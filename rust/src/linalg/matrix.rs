//! Dense row-major `f64` matrix.

use crate::util::rng::Pcg64;

/// Dense row-major matrix of `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn filled(rows: usize, cols: usize, value: f64) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![value; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Matrix of Uniform[-1,1) entries.
    pub fn random(rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Max-norm distance to another matrix (shape must match).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul_is_noop() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn known_matmul() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn transpose_involution() {
        let mut r = Pcg64::new(4);
        let a = Matrix::random(3, 5, &mut r);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(&[&[1.0, -1.0, 2.0], &[0.0, 3.0, 1.0]]);
        let x = vec![2.0, 1.0, 0.5];
        assert_eq!(a.matvec(&x), vec![2.0, 3.5]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        a.matmul(&b);
    }
}

//! Dense simplex linear-program solver.
//!
//! This is the substrate behind the **Gavel** and **POP** baselines (§2.3,
//! Fig. 2/14): Gavel formulates scheduling + packing as one LP whose variable
//! count grows with jobs (and job pairs when GPU sharing is on), which is
//! exactly the scalability bottleneck Tesserae's graph-matching formulation
//! avoids. We implement the standard-form tableau simplex with Bland's
//! anti-cycling rule:
//!
//! maximize    cᵀx
//! subject to  A x ≤ b,  x ≥ 0,  b ≥ 0
//!
//! All of the Gavel-style allocation problems in this repo fit that form
//! (capacities are non-negative, allocations are fractions in [0,1] expressed
//! via explicit `x_j ≤ 1` rows).

use super::matrix::Matrix;

/// LP instance in standard inequality form.
#[derive(Debug, Clone)]
pub struct Lp {
    /// Objective coefficients (maximized), length n.
    pub objective: Vec<f64>,
    /// Constraint matrix, m × n.
    pub constraints: Matrix,
    /// Right-hand sides, length m; must be non-negative.
    pub rhs: Vec<f64>,
}

/// Solution of an LP.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpError {
    Unbounded,
    /// Iteration limit exceeded — treated as a solver failure upstream.
    Stalled,
    BadInput(String),
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Unbounded => write!(f, "LP is unbounded"),
            LpError::Stalled => write!(f, "simplex exceeded iteration limit"),
            LpError::BadInput(m) => write!(f, "bad LP input: {m}"),
        }
    }
}

impl std::error::Error for LpError {}

const EPS: f64 = 1e-9;

/// Solve an LP with the dense tableau simplex method.
pub fn solve_lp(lp: &Lp) -> Result<LpSolution, LpError> {
    let m = lp.rhs.len();
    let n = lp.objective.len();
    if lp.constraints.rows() != m || lp.constraints.cols() != n {
        return Err(LpError::BadInput(format!(
            "constraint matrix {}x{} does not match rhs {} / objective {}",
            lp.constraints.rows(),
            lp.constraints.cols(),
            m,
            n
        )));
    }
    if lp.rhs.iter().any(|&b| b < 0.0) {
        return Err(LpError::BadInput("rhs must be non-negative".into()));
    }

    // Tableau: m rows of [A | I | b], objective row [-c | 0 | 0].
    let width = n + m + 1;
    let mut t = vec![0.0f64; (m + 1) * width];
    let idx = |r: usize, c: usize| r * width + c;
    for r in 0..m {
        for c in 0..n {
            t[idx(r, c)] = lp.constraints.get(r, c);
        }
        t[idx(r, n + r)] = 1.0;
        t[idx(r, n + m)] = lp.rhs[r];
    }
    for c in 0..n {
        t[idx(m, c)] = -lp.objective[c];
    }

    // basis[r] = column currently basic in row r (starts as slack columns).
    let mut basis: Vec<usize> = (n..n + m).collect();
    let max_iters = 50 * (m + n).max(64);
    let mut iters = 0usize;

    loop {
        // Entering column: most negative reduced cost (Dantzig); fall back to
        // Bland's rule (lowest index with negative cost) when stalling risk
        // appears (degenerate pivots).
        let use_bland = iters > 10 * (m + n);
        let mut enter: Option<usize> = None;
        let mut best = -EPS;
        for c in 0..n + m {
            let rc = t[idx(m, c)];
            if rc < -EPS {
                if use_bland {
                    enter = Some(c);
                    break;
                }
                if rc < best {
                    best = rc;
                    enter = Some(c);
                }
            }
        }
        let Some(ecol) = enter else {
            // Optimal.
            let mut x = vec![0.0; n];
            for r in 0..m {
                if basis[r] < n {
                    x[basis[r]] = t[idx(r, n + m)];
                }
            }
            let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
            return Ok(LpSolution {
                x,
                objective,
                iterations: iters,
            });
        };

        // Leaving row: min ratio test (Bland tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for r in 0..m {
            let a = t[idx(r, ecol)];
            if a > EPS {
                let ratio = t[idx(r, n + m)] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map(|lr| basis[r] < basis[lr]).unwrap_or(true))
                {
                    best_ratio = ratio;
                    leave = Some(r);
                }
            }
        }
        let Some(lrow) = leave else {
            return Err(LpError::Unbounded);
        };

        // Pivot.
        let piv = t[idx(lrow, ecol)];
        for c in 0..width {
            t[idx(lrow, c)] /= piv;
        }
        for r in 0..=m {
            if r == lrow {
                continue;
            }
            let f = t[idx(r, ecol)];
            if f.abs() > EPS {
                for c in 0..width {
                    t[idx(r, c)] -= f * t[idx(lrow, c)];
                }
            }
        }
        basis[lrow] = ecol;
        iters += 1;
        if iters > max_iters {
            return Err(LpError::Stalled);
        }
    }
}

impl Lp {
    /// Helper: build an LP with box constraints `x_j ≤ ub_j` appended to the
    /// structural constraints. (x ≥ 0 is implicit in standard form.)
    pub fn with_upper_bounds(
        objective: Vec<f64>,
        constraints: Matrix,
        rhs: Vec<f64>,
        ub: &[f64],
    ) -> Lp {
        let n = objective.len();
        assert_eq!(ub.len(), n);
        let m = rhs.len();
        let mut a = Matrix::zeros(m + n, n);
        for r in 0..m {
            for c in 0..n {
                a.set(r, c, constraints.get(r, c));
            }
        }
        let mut b = rhs;
        for j in 0..n {
            a.set(m + j, j, 1.0);
            b.push(ub[j]);
        }
        Lp {
            objective,
            constraints: a,
            rhs: b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn textbook_two_vars() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> x=2, y=6, obj=36.
        let lp = Lp {
            objective: vec![3.0, 5.0],
            constraints: Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0], &[3.0, 2.0]]),
            rhs: vec![4.0, 12.0, 18.0],
        };
        let s = solve_lp(&lp).unwrap();
        assert!((s.objective - 36.0).abs() < 1e-8);
        assert!((s.x[0] - 2.0).abs() < 1e-8);
        assert!((s.x[1] - 6.0).abs() < 1e-8);
    }

    #[test]
    fn detects_unbounded() {
        let lp = Lp {
            objective: vec![1.0, 0.0],
            constraints: Matrix::from_rows(&[&[0.0, 1.0]]),
            rhs: vec![1.0],
        };
        assert_eq!(solve_lp(&lp).unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic Beale cycling example (cycles under Dantzig without a
        // safeguard); our Bland fallback must terminate at obj = 0.05.
        let lp = Lp {
            objective: vec![0.75, -150.0, 0.02, -6.0],
            constraints: Matrix::from_rows(&[
                &[0.25, -60.0, -0.04, 9.0],
                &[0.5, -90.0, -0.02, 3.0],
                &[0.0, 0.0, 1.0, 0.0],
            ]),
            rhs: vec![0.0, 0.0, 1.0],
        };
        let s = solve_lp(&lp).unwrap();
        assert!((s.objective - 0.05).abs() < 1e-8, "obj {}", s.objective);
    }

    #[test]
    fn fractional_knapsack_matches_greedy() {
        // A Gavel-shaped allocation: max Σ p_j x_j  s.t. Σ g_j x_j <= G,
        // 0 <= x <= 1. Simplex must match the greedy fractional solution.
        forall(
            "lp-knapsack == greedy",
            7,
            30,
            |r| {
                let n = 2 + r.below(12) as usize;
                let p: Vec<f64> = (0..n).map(|_| r.range_f64(0.1, 4.0)).collect();
                let g: Vec<f64> = (0..n).map(|_| r.range_u64(1, 8) as f64).collect();
                let cap = r.range_f64(1.0, g.iter().sum::<f64>());
                (p, g, cap)
            },
            |(p, g, cap)| {
                let n = p.len();
                let lp = Lp::with_upper_bounds(
                    p.clone(),
                    Matrix::from_vec(1, n, g.clone()),
                    vec![*cap],
                    &vec![1.0; n],
                );
                let s = solve_lp(&lp).map_err(|e| e.to_string())?;
                // Greedy by density.
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    (p[b] / g[b]).partial_cmp(&(p[a] / g[a])).unwrap()
                });
                let mut rem = *cap;
                let mut obj = 0.0;
                for &j in &order {
                    let take = (rem / g[j]).min(1.0).max(0.0);
                    obj += take * p[j];
                    rem -= take * g[j];
                    if rem <= 0.0 {
                        break;
                    }
                }
                crate::util::prop::approx_eq(s.objective, obj, 1e-6)
            },
        );
    }

    #[test]
    fn bad_input_rejected() {
        let lp = Lp {
            objective: vec![1.0],
            constraints: Matrix::zeros(1, 1),
            rhs: vec![-1.0],
        };
        assert!(matches!(solve_lp(&lp), Err(LpError::BadInput(_))));
    }

    #[test]
    fn solution_is_feasible() {
        forall(
            "lp solution feasible",
            13,
            25,
            |r| {
                let n = 1 + r.below(6) as usize;
                let m = 1 + r.below(6) as usize;
                let c: Vec<f64> = (0..n).map(|_| r.range_f64(0.0, 2.0)).collect();
                let mut a = Matrix::zeros(m, n);
                for i in 0..m {
                    for j in 0..n {
                        a.set(i, j, r.range_f64(0.0, 2.0));
                    }
                }
                let b: Vec<f64> = (0..m).map(|_| r.range_f64(0.5, 5.0)).collect();
                Lp {
                    objective: c,
                    constraints: a,
                    rhs: b,
                }
            },
            |lp| {
                // Non-negative A with positive b: bounded unless a zero
                // column has positive objective — filter that case.
                match solve_lp(lp) {
                    Ok(s) => {
                        for (i, row) in (0..lp.rhs.len()).map(|i| (i, lp.constraints.row(i))) {
                            let lhs: f64 = row.iter().zip(&s.x).map(|(a, x)| a * x).sum();
                            if lhs > lp.rhs[i] + 1e-6 {
                                return Err(format!("row {i} violated: {lhs} > {}", lp.rhs[i]));
                            }
                        }
                        if s.x.iter().any(|&x| x < -1e-9) {
                            return Err("negative x".into());
                        }
                        Ok(())
                    }
                    Err(LpError::Unbounded) => Ok(()), // possible with zero columns
                    Err(e) => Err(e.to_string()),
                }
            },
        );
    }
}

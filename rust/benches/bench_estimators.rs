//! Estimator benchmarks: Fig. 18 (estimator quality vs profiling budget),
//! Fig. 16 (noise sensitivity) and construction-cost micro-timings.
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs only the
//! construction micro-timings on the quick harness.

use tesserae::cluster::GpuType;
use tesserae::estimator::{
    LinearBoEstimator, MatrixCompletionEstimator, OracleEstimator, ThroughputSource,
};
use tesserae::experiments::{ablations, Scale};
use tesserae::profiler::Profiler;
use tesserae::util::benchutil::{smoke_mode, Bench};

fn main() {
    let smoke = smoke_mode();
    if !smoke {
        let scale = Scale::standard();
        println!("{}", ablations::fig18_estimators(&scale));
        println!(
            "{}",
            ablations::fig16_noise_sensitivity(&scale, &[0.0, 0.25, 0.5, 1.0])
        );
    }

    let mut bench = if smoke { Bench::quick() } else { Bench::new() };
    let p = Profiler::new(GpuType::A100, 3);
    bench.run("oracle build", || {
        OracleEstimator::new(p.clone()).profiling_samples()
    });
    bench.run("linear+bo build (budget 6)", || {
        LinearBoEstimator::new(p.clone(), 6, 1).profiling_samples()
    });
    bench.run("matrix-completion build (40%)", || {
        MatrixCompletionEstimator::new(p.clone(), 0.4, 1).profiling_samples()
    });
    println!("{}", bench.report());
    if smoke {
        println!("smoke mode: figure sweeps skipped");
    }
}

//! Matching-service benchmark (ISSUE 2 acceptance artifact): Tesserae
//! migration decision time with the batched / pruned / cached service vs
//! per-instance sequential solves at 16/32/64-node scale, on sparse and
//! half-full clusters. Asserts outcome parity in-line and emits
//! `BENCH_matching_service.json` with instances/sec, prune/dedup/cache-hit
//! rates and the batched-vs-sequential speedup. The acceptance line is
//! ≥2x at 64 nodes sparse (where pruning and caching bite hardest).
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs one tiny
//! config, skips the acceptance assert and writes no JSON.

use std::time::Instant;

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::matching::{HungarianEngine, MatchingService, MatchingServiceStats, ServiceConfig};
use tesserae::policies::placement::{migrate_with, MigrationMode};
use tesserae::util::json::Json;
use tesserae::util::rng::Pcg64;

/// A sequence of `rounds + 1` consolidated plans — the allocator's shape,
/// `jobs` single-GPU slots filled from GPU 0 — where each round replaces
/// ~15% of the jobs with fresh arrivals on the same slots. Consecutive
/// plans are the (prev, next) inputs of one migration round, so the warm
/// service sees genuine churn: unchanged node pairs should hit the cache,
/// changed ones must invalidate and re-solve. Everything beyond the
/// occupied prefix is empty nodes — the sparse regime ROADMAP's 64-node
/// hot-path item is about.
fn plan_sequence(spec: &ClusterSpec, jobs: usize, rounds: usize, seed: u64) -> Vec<PlacementPlan> {
    let total = spec.total_gpus();
    let jobs = jobs.min(total);
    let mut rng = Pcg64::new(seed);
    let mut ids: Vec<u64> = (0..jobs as u64).collect();
    let mut fresh = 1_000_000u64;
    let mut plans = Vec::with_capacity(rounds + 1);
    for _ in 0..=rounds {
        let mut p = PlacementPlan::new(total);
        for (slot, &id) in ids.iter().enumerate() {
            p.place(id, &[slot]);
        }
        plans.push(p);
        for id in ids.iter_mut() {
            if rng.f64() < 0.15 {
                *id = fresh;
                fresh += 1;
            }
        }
    }
    plans
}

fn run_rounds(
    spec: &ClusterSpec,
    plans: &[PlacementPlan],
    svc: &mut MatchingService,
) -> (f64, MatchingServiceStats, PlacementPlan, Vec<usize>) {
    let rounds = plans.len() - 1;
    let t0 = Instant::now();
    let mut total = MatchingServiceStats::default();
    let mut last_plan = None;
    let mut migrations = Vec::with_capacity(rounds);
    for w in plans.windows(2) {
        let out = migrate_with(
            spec,
            &w[0],
            &w[1],
            MigrationMode::Tesserae,
            &HungarianEngine,
            svc,
        );
        // Accumulate across rounds: round 1 is cold, later rounds mix warm
        // cache hits (unchanged pairs) with re-solves (churned pairs).
        let s = out.service;
        total.instances += s.instances;
        total.pruned += s.pruned;
        total.deduped += s.deduped;
        total.cache_hits += s.cache_hits;
        total.built += s.built;
        total.solved += s.solved;
        total.solve_wall_s += s.solve_wall_s;
        migrations.push(out.migrations);
        last_plan = Some(out.plan);
    }
    (
        t0.elapsed().as_secs_f64() / rounds as f64,
        total,
        last_plan.expect("at least one round"),
        migrations,
    )
}

fn main() {
    const ROUNDS: usize = 5;
    let smoke = tesserae::util::benchutil::smoke_mode();
    let mut entries = Vec::new();
    println!("== Tesserae migration: matching service vs sequential per-instance solves ==");
    println!("   (per-round average over {ROUNDS} rounds; service carries its cache across rounds)");
    let configs: Vec<(usize, f64, &str)> = if smoke {
        vec![(4, 0.5, "smoke")]
    } else {
        vec![
            (16, 0.15, "sparse"),
            (32, 0.15, "sparse"),
            (64, 0.15, "sparse"),
            (64, 0.5, "half-full"),
        ]
    };
    for (nodes, occupancy, label) in configs {
        let spec = ClusterSpec::new(nodes, 8, GpuType::A100);
        let jobs = ((spec.total_gpus() as f64) * occupancy) as usize;
        let plans = plan_sequence(&spec, jobs, ROUNDS, 42 + nodes as u64);

        let mut seq_svc = MatchingService::new(ServiceConfig::sequential_reference());
        let (seq_s, _, seq_plan, seq_migrations) = run_rounds(&spec, &plans, &mut seq_svc);

        let mut svc = MatchingService::with_defaults();
        let (svc_s, stats, svc_plan, svc_migrations) = run_rounds(&spec, &plans, &mut svc);

        assert_eq!(svc_plan, seq_plan, "service diverged from sequential solves");
        assert_eq!(svc_migrations, seq_migrations, "per-round migration counts diverged");

        let speedup = seq_s / svc_s.max(1e-12);
        let inst_per_s = stats.instances as f64 / (svc_s * ROUNDS as f64).max(1e-12);
        let rate = |x: usize| x as f64 / stats.instances.max(1) as f64;
        println!(
            "{nodes:>3}x8 {label:<9} ({jobs:>3} jobs): service {:>9.3}ms vs sequential {:>9.3}ms = {speedup:>6.1}x | \
             {} inst over {ROUNDS} rounds ({} pruned, {} dedup, {} cached, {} solved), {:.0} inst/s",
            svc_s * 1e3,
            seq_s * 1e3,
            stats.instances,
            stats.pruned,
            stats.deduped,
            stats.cache_hits,
            stats.solved,
            inst_per_s,
        );
        entries.push(Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("gpus_per_node", Json::num(8.0)),
            ("workload", Json::str(label)),
            ("occupancy", Json::num(occupancy)),
            ("jobs", Json::num(jobs as f64)),
            ("rounds", Json::num(ROUNDS as f64)),
            ("instances_total", Json::num(stats.instances as f64)),
            ("pruned", Json::num(stats.pruned as f64)),
            ("deduped", Json::num(stats.deduped as f64)),
            ("cache_hits", Json::num(stats.cache_hits as f64)),
            ("solved", Json::num(stats.solved as f64)),
            ("prune_rate", Json::num(rate(stats.pruned))),
            ("dedup_rate", Json::num(rate(stats.deduped))),
            ("cache_hit_rate", Json::num(rate(stats.cache_hits))),
            ("instances_per_sec", Json::num(inst_per_s)),
            ("service_round_s", Json::num(svc_s)),
            ("sequential_round_s", Json::num(seq_s)),
            ("speedup", Json::num(speedup)),
        ]));
        if nodes == 64 && label == "sparse" {
            assert!(
                speedup >= 2.0,
                "acceptance: 64-node sparse speedup {speedup:.2}x < 2x"
            );
        }
    }
    if smoke {
        println!("smoke mode: tiny config, acceptance assert and JSON output skipped");
        return;
    }

    let json = Json::obj(vec![
        ("bench", Json::str("matching_service")),
        ("meta", tesserae::util::benchutil::bench_meta()),
        ("entries", Json::arr(entries)),
    ]);
    match std::fs::write("BENCH_matching_service.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_matching_service.json"),
        Err(e) => println!("could not write BENCH_matching_service.json: {e}"),
    }
}

//! Sharded-coordinator benchmark (ISSUE 9 acceptance artifact).
//!
//! Three arms:
//!  1. **Parity** — `Sharded(1)` vs plain Tesserae-T over churned
//!     consecutive rounds: plans, strategies, packed pairs and migration
//!     counts asserted bit-identical (shards=1 must be a pure wrapper).
//!     Runs in smoke mode too.
//!  2. **Round speedup** — one churned decision at 2048 nodes x 4 GPUs
//!     (4096 active jobs): Sharded-16 vs the unsharded full-cluster
//!     scheduler. Acceptance: speedup >= 4x.
//!  3. **Quality** — simulated avg JCT at the same 2048-node scale on a
//!     lightly-loaded trace: Sharded-16 vs full-cluster. Acceptance:
//!     |avg JCT delta| <= 5%.
//!
//! Emits `BENCH_sharded.json`. Smoke mode (`--smoke` or
//! TESSERAE_BENCH_SMOKE=1) runs the parity arm at tiny scale only and
//! writes no JSON.

use std::sync::Arc;

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use tesserae::experiments::scalability::{
    churn_active_jobs, measure_decision, measure_sharded_decision, synthetic_active_jobs,
};
use tesserae::experiments::{self, build_scheduler, Scale, SchedKind};
use tesserae::matching::HungarianEngine;
use tesserae::profiler::Profiler;
use tesserae::schedulers::{RoundDecision, RoundInput};
use tesserae::util::benchutil::{bench_meta, smoke_mode};
use tesserae::util::json::Json;

/// Drive `rounds` consecutive churned decisions with a fresh scheduler
/// stack and return every round's decision.
fn run_rounds(kind: SchedKind, n_jobs: usize, spec: &ClusterSpec, seed: u64) -> Vec<RoundDecision> {
    const ROUNDS: u64 = 4;
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth)));
    let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
    let mut active = synthetic_active_jobs(n_jobs, seed);
    let mut prev = PlacementPlan::new(spec.total_gpus());
    let mut decisions = Vec::with_capacity(ROUNDS as usize);
    for round in 0..ROUNDS {
        let d = sched.decide(&RoundInput {
            now: 1e6 + round as f64 * 360.0,
            round,
            active: &active,
            prev_plan: &prev,
            spec,
            health: None,
        });
        prev = d.plan.clone();
        active = churn_active_jobs(&active, seed ^ (round + 1));
        decisions.push(d);
    }
    decisions
}

fn main() {
    let smoke = smoke_mode();

    // Arm 1: shards=1 parity. A one-shard coordinator routes every job to
    // the single sub-scheduler with the whole cluster, so its decisions
    // must be bit-identical to running that scheduler directly.
    let (nodes, gpn) = if smoke { (4, 2) } else { (16, 4) };
    let spec = ClusterSpec::new(nodes, gpn, GpuType::A100);
    let n_jobs = spec.total_gpus();
    println!("== Parity: sharded(1) vs tesserae-t, {nodes}x{gpn}, {n_jobs} jobs ==");
    let base = run_rounds(SchedKind::TesseraeT, n_jobs, &spec, 42);
    let wrapped = run_rounds(SchedKind::Sharded(1), n_jobs, &spec, 42);
    for (round, (b, w)) in base.iter().zip(&wrapped).enumerate() {
        assert_eq!(b.plan, w.plan, "round {round}: plans diverged");
        assert_eq!(b.strategies, w.strategies, "round {round}: strategies diverged");
        assert_eq!(b.packed_pairs, w.packed_pairs, "round {round}: packed pairs diverged");
        assert_eq!(b.migrations, w.migrations, "round {round}: migration counts diverged");
    }
    println!("   {} rounds bit-identical", base.len());

    if smoke {
        println!("smoke mode: speedup/quality arms and JSON output skipped");
        return;
    }

    // Arm 2: round-time speedup at scale. One warm + one measured churned
    // decision per arm (the scale sweep's protocol).
    const SPEEDUP_NODES: usize = 2048;
    const SHARDS: usize = 16;
    let big = ClusterSpec::new(SPEEDUP_NODES, 4, GpuType::A100);
    let big_jobs = 4096;
    println!(
        "== Round speedup: sharded({SHARDS}) vs unsharded, {SPEEDUP_NODES}x4, {big_jobs} jobs =="
    );
    let unsharded_s = measure_decision(SchedKind::TesseraeT, big_jobs, &big, 17).total_s;
    let (sharded_d, shard_s) = measure_sharded_decision(SHARDS, big_jobs, &big, 17);
    let sharded_total = sharded_d.total_s;
    let shard_max = shard_s.iter().cloned().fold(0.0, f64::max);
    let speedup = unsharded_s / sharded_total.max(1e-12);
    println!(
        "   unsharded {unsharded_s:.3}s vs sharded {sharded_total:.3}s \
         (max shard {shard_max:.3}s) = {speedup:.2}x"
    );

    // Arm 3: quality at the same cluster scale. A lightly-loaded trace
    // keeps full-cluster simulation tractable at 8192 GPUs; sharding
    // trades global placement optimality for round time, and the bound is
    // the issue's 5% avg-JCT envelope.
    let scale = Scale {
        jobs: 300,
        nodes: SPEEDUP_NODES,
        gpus_per_node: 4,
        jobs_per_hour: 160.0,
        seed: 7,
    };
    let trace = scale.shockwave_trace();
    let qspec = scale.spec(GpuType::A100);
    println!("== Quality: simulated avg JCT at {SPEEDUP_NODES}x4, {} jobs ==", scale.jobs);
    let full = experiments::run_sim(SchedKind::TesseraeT, &trace, qspec, scale.seed, 0.0);
    let shard = experiments::run_sim(SchedKind::Sharded(SHARDS), &trace, qspec, scale.seed, 0.0);
    let jct_delta = 100.0 * (shard.avg_jct - full.avg_jct) / full.avg_jct.max(1e-12);
    println!(
        "   full-cluster {:.0}s vs sharded {:.0}s avg JCT = {jct_delta:+.2}%",
        full.avg_jct, shard.avg_jct
    );

    assert!(
        speedup >= 4.0,
        "acceptance: sharded round speedup {speedup:.2}x < 4x at {SPEEDUP_NODES} nodes"
    );
    assert!(
        jct_delta.abs() <= 5.0,
        "acceptance: sharded avg-JCT delta {jct_delta:+.2}% outside the 5% envelope"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("sharded")),
        ("meta", bench_meta()),
        (
            "entries",
            Json::arr(vec![
                Json::obj(vec![
                    ("arm", Json::str("parity")),
                    ("nodes", Json::num(nodes as f64)),
                    ("jobs", Json::num(n_jobs as f64)),
                    ("rounds", Json::num(base.len() as f64)),
                ]),
                Json::obj(vec![
                    ("arm", Json::str("round_speedup")),
                    ("nodes", Json::num(SPEEDUP_NODES as f64)),
                    ("jobs", Json::num(big_jobs as f64)),
                    ("shards", Json::num(SHARDS as f64)),
                    ("unsharded_s", Json::num(unsharded_s)),
                    ("sharded_s", Json::num(sharded_total)),
                    ("shard_max_s", Json::num(shard_max)),
                    ("speedup", Json::num(speedup)),
                ]),
                Json::obj(vec![
                    ("arm", Json::str("quality")),
                    ("nodes", Json::num(SPEEDUP_NODES as f64)),
                    ("trace_jobs", Json::num(scale.jobs as f64)),
                    ("shards", Json::num(SHARDS as f64)),
                    ("full_avg_jct", Json::num(full.avg_jct)),
                    ("sharded_avg_jct", Json::num(shard.avg_jct)),
                    ("jct_delta_pct", Json::num(jct_delta)),
                ]),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_sharded.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_sharded.json"),
        Err(e) => println!("could not write BENCH_sharded.json: {e}"),
    }
}

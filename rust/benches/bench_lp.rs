//! LP-core benchmark: the dense tableau simplex vs the sparse revised
//! simplex on real Gavel-shaped allocation instances, cold vs
//! warm-started, across job counts.
//!
//! Emits `BENCH_lp.json` and asserts the PR's acceptance criteria inline:
//! the two solvers agree on the optimal objective within 1e-6, and the
//! warm-started round-over-round revised solve is ≥ 5x faster than a cold
//! dense solve at 1024 jobs (in practice it is orders of magnitude
//! faster; 5x is the floor that keeps the assert robust on loaded CI
//! machines).
//!
//! Scale override: TESSERAE_BENCH_LP_SIZES=64,256,1024

use std::time::Instant;

use tesserae::experiments::scalability::synthetic_active_jobs;
use tesserae::linalg::{solve_lp, solve_sparse_lp};
use tesserae::schedulers::gavel::{
    allocation_objective_into, build_allocation_lp, candidate_pairs,
};
use tesserae::schedulers::GavelObjective;
use tesserae::util::benchutil::{fmt_duration, Table};
use tesserae::util::json::Json;

const TOTAL_GPUS: usize = 256;
const WARM_ROUNDS: usize = 8;

fn sizes() -> Vec<usize> {
    std::env::var("TESSERAE_BENCH_LP_SIZES")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![64, 256, 1024])
}

fn main() {
    let source: std::sync::Arc<dyn tesserae::estimator::ThroughputSource> =
        std::sync::Arc::new(tesserae::estimator::CachedSource::new(
            tesserae::estimator::OracleEstimator::new(tesserae::profiler::Profiler::new(
                tesserae::cluster::GpuType::A100,
                21,
            )),
        ));

    let mut t = Table::new(&[
        "jobs",
        "vars",
        "rows",
        "dense cold",
        "revised cold",
        "revised warm (avg)",
        "warm vs dense",
    ]);
    let mut cases = Vec::new();
    let mut speedup_at_1024: Option<f64> = None;

    for n in sizes() {
        let mut jobs = synthetic_active_jobs(n, 21);
        let pairs = candidate_pairs(&jobs, true, 6);
        let mut lp = build_allocation_lp(&jobs, &pairs, TOTAL_GPUS);
        allocation_objective_into(
            GavelObjective::Las,
            &jobs,
            &pairs,
            source.as_ref(),
            &mut lp.objective,
        );

        // Cold solves: revised, then the retained dense tableau on the
        // materialized instance (bounds as explicit rows — the seed
        // formulation).
        let t0 = Instant::now();
        let (rev_cold, mut warm) = solve_sparse_lp(&lp, None).expect("revised cold solve");
        let revised_cold_s = t0.elapsed().as_secs_f64();

        let dense_lp = lp.to_dense_lp();
        let t0 = Instant::now();
        let dense = solve_lp(&dense_lp).expect("dense cold solve");
        let dense_cold_s = t0.elapsed().as_secs_f64();

        assert!(
            (rev_cold.objective - dense.objective).abs()
                <= 1e-6 * (1.0 + dense.objective.abs()),
            "{n} jobs: revised {} vs dense {} objective",
            rev_cold.objective,
            dense.objective
        );

        // Warm rounds: drift the LAS weights (the round-over-round Gavel
        // case — attained service grows, structure unchanged), re-patch
        // the objective in place and re-solve from the previous basis.
        let mut warm_total_s = 0.0;
        let mut warm_iters = 0usize;
        for _round in 0..WARM_ROUNDS {
            for (i, j) in jobs.iter_mut().enumerate() {
                j.attained_service += 360.0 * (1 + i % 5) as f64;
            }
            allocation_objective_into(
                GavelObjective::Las,
                &jobs,
                &pairs,
                source.as_ref(),
                &mut lp.objective,
            );
            let t0 = Instant::now();
            let (sol, next_warm) = solve_sparse_lp(&lp, Some(&warm)).expect("warm solve");
            warm_total_s += t0.elapsed().as_secs_f64();
            warm_iters += sol.iterations;
            warm = next_warm;
        }
        let warm_avg_s = warm_total_s / WARM_ROUNDS as f64;

        // Final-round parity: warm must land on the same optimum a cold
        // revised solve of the current objective finds.
        let (final_cold, _) = solve_sparse_lp(&lp, None).expect("final cold solve");
        let (final_warm, _) = solve_sparse_lp(&lp, Some(&warm)).expect("final warm solve");
        assert!(
            (final_warm.objective - final_cold.objective).abs()
                <= 1e-8 * (1.0 + final_cold.objective.abs()),
            "{n} jobs: warm {} vs cold {} after drift",
            final_warm.objective,
            final_cold.objective
        );

        let speedup = dense_cold_s / warm_avg_s.max(1e-9);
        if n == 1024 {
            speedup_at_1024 = Some(speedup);
        }
        t.row(&[
            format!("{n}"),
            format!("{}", lp.num_vars()),
            format!("{}", lp.num_rows()),
            fmt_duration(dense_cold_s),
            fmt_duration(revised_cold_s),
            fmt_duration(warm_avg_s),
            format!("{speedup:.1}x"),
        ]);
        cases.push(Json::obj(vec![
            ("jobs", Json::num(n as f64)),
            ("vars", Json::num(lp.num_vars() as f64)),
            ("rows", Json::num(lp.num_rows() as f64)),
            ("pairs", Json::num(pairs.len() as f64)),
            ("dense_cold_s", Json::num(dense_cold_s)),
            ("revised_cold_s", Json::num(revised_cold_s)),
            ("revised_warm_avg_s", Json::num(warm_avg_s)),
            ("warm_rounds", Json::num(WARM_ROUNDS as f64)),
            ("dense_objective", Json::num(dense.objective)),
            ("revised_objective", Json::num(rev_cold.objective)),
            ("cold_iterations", Json::num(rev_cold.iterations as f64)),
            (
                "warm_avg_iterations",
                Json::num(warm_iters as f64 / WARM_ROUNDS as f64),
            ),
            ("warm_vs_dense_speedup", Json::num(speedup)),
        ]));
    }

    println!(
        "LP core: dense tableau vs sparse revised simplex (Gavel-shaped, {TOTAL_GPUS} GPUs)\n{}",
        t.render()
    );

    // Acceptance: warm-started round-over-round Gavel solves are ≥ 5x
    // faster than cold dense solves at 1024 jobs.
    if let Some(speedup) = speedup_at_1024 {
        assert!(
            speedup >= 5.0,
            "acceptance failed: warm revised only {speedup:.2}x vs cold dense at 1024 jobs"
        );
        println!("acceptance: warm revised {speedup:.1}x >= 5x vs cold dense at 1024 jobs");
    } else {
        println!("note: 1024-job case not in TESSERAE_BENCH_LP_SIZES; acceptance skipped");
    }

    let json = Json::obj(vec![
        ("bench", Json::str("lp")),
        ("total_gpus", Json::num(TOTAL_GPUS as f64)),
        ("cases", Json::arr(cases)),
    ]);
    match std::fs::write("BENCH_lp.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_lp.json"),
        Err(e) => println!("could not write BENCH_lp.json: {e}"),
    }
}

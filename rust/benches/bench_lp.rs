//! LP-core benchmark: the dense tableau simplex vs the sparse revised
//! simplex on real Gavel-shaped allocation instances — cold, warm-started
//! (objective drift) and dual-simplex *repaired* (job arrival/departure
//! churn) — across job counts.
//!
//! Emits `BENCH_lp.json` and asserts the PR's acceptance criteria inline:
//! the solvers agree on the optimal objective within 1e-6, the
//! warm-started round-over-round revised solve is ≥ 5x faster than a cold
//! dense solve at 1024 jobs, and the remap+repair+warm re-solve after a
//! single-job arrival or departure is ≥ 3x faster than a cold sparse
//! re-solve at 1024 jobs (floors chosen to stay robust on loaded CI
//! machines).
//!
//! Scale override: TESSERAE_BENCH_LP_SIZES=64,256,1024
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs tiny sizes,
//! skips the size-gated acceptance asserts and writes no JSON.

use std::time::Instant;

use tesserae::experiments::scalability::synthetic_active_jobs;
use tesserae::linalg::{repair_warm_start, solve_lp, solve_sparse_lp};
use tesserae::schedulers::gavel::{
    allocation_lp_maps, allocation_objective_into, build_allocation_lp, candidate_pairs,
};
use tesserae::schedulers::GavelObjective;
use tesserae::util::benchutil::{fmt_duration, smoke_mode, Table};
use tesserae::util::json::Json;

const TOTAL_GPUS: usize = 256;
const WARM_ROUNDS: usize = 8;
/// Alternating single-job departure / re-arrival events per size.
const CHURN_EVENTS: usize = 8;
const PAIR_WINDOW: usize = 6;

fn sizes(smoke: bool) -> Vec<usize> {
    if smoke {
        return vec![16];
    }
    std::env::var("TESSERAE_BENCH_LP_SIZES")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![64, 256, 1024])
}

fn main() {
    let smoke = smoke_mode();
    let source: std::sync::Arc<dyn tesserae::estimator::ThroughputSource> =
        std::sync::Arc::new(tesserae::estimator::CachedSource::new(
            tesserae::estimator::OracleEstimator::new(tesserae::profiler::Profiler::new(
                tesserae::cluster::GpuType::A100,
                21,
            )),
        ));

    let mut t = Table::new(&[
        "jobs",
        "vars",
        "rows",
        "dense cold",
        "revised cold",
        "revised warm (avg)",
        "warm vs dense",
        "churn cold (avg)",
        "churn repair (avg)",
        "repair vs cold",
    ]);
    let mut cases = Vec::new();
    let mut speedup_at_1024: Option<f64> = None;
    let mut repair_speedup_at_1024: Option<f64> = None;

    for n in sizes(smoke) {
        let mut jobs = synthetic_active_jobs(n, 21);
        let mut pairs = candidate_pairs(&jobs, true, PAIR_WINDOW);
        let mut lp = build_allocation_lp(&jobs, &pairs, TOTAL_GPUS);
        allocation_objective_into(
            GavelObjective::Las,
            &jobs,
            &pairs,
            source.as_ref(),
            &mut lp.objective,
        );
        let (vars0, rows0) = (lp.num_vars(), lp.num_rows());

        // Cold solves: revised, then the retained dense tableau on the
        // materialized instance (bounds as explicit rows — the seed
        // formulation).
        let t0 = Instant::now();
        let (rev_cold, mut warm) = solve_sparse_lp(&lp, None).expect("revised cold solve");
        let revised_cold_s = t0.elapsed().as_secs_f64();

        let dense_lp = lp.to_dense_lp();
        let t0 = Instant::now();
        let dense = solve_lp(&dense_lp).expect("dense cold solve");
        let dense_cold_s = t0.elapsed().as_secs_f64();

        assert!(
            (rev_cold.objective - dense.objective).abs()
                <= 1e-6 * (1.0 + dense.objective.abs()),
            "{n} jobs: revised {} vs dense {} objective",
            rev_cold.objective,
            dense.objective
        );

        // Warm rounds: drift the LAS weights (the round-over-round Gavel
        // case — attained service grows, structure unchanged), re-patch
        // the objective in place and re-solve from the previous basis.
        let mut warm_total_s = 0.0;
        let mut warm_iters = 0usize;
        for _round in 0..WARM_ROUNDS {
            for (i, j) in jobs.iter_mut().enumerate() {
                j.attained_service += 360.0 * (1 + i % 5) as f64;
            }
            allocation_objective_into(
                GavelObjective::Las,
                &jobs,
                &pairs,
                source.as_ref(),
                &mut lp.objective,
            );
            let t0 = Instant::now();
            let (sol, next_warm) = solve_sparse_lp(&lp, Some(&warm)).expect("warm solve");
            warm_total_s += t0.elapsed().as_secs_f64();
            warm_iters += sol.iterations;
            warm = next_warm;
        }
        let warm_avg_s = warm_total_s / WARM_ROUNDS as f64;

        // Mid-bench parity: warm must land on the same optimum a cold
        // revised solve of the current objective finds.
        let (final_cold, _) = solve_sparse_lp(&lp, None).expect("final cold solve");
        let (final_warm, next_warm) =
            solve_sparse_lp(&lp, Some(&warm)).expect("final warm solve");
        warm = next_warm;
        assert!(
            (final_warm.objective - final_cold.objective).abs()
                <= 1e-8 * (1.0 + final_cold.objective.abs()),
            "{n} jobs: warm {} vs cold {} after drift",
            final_warm.objective,
            final_cold.objective
        );

        // Churn rounds: a single job departs (or re-arrives), changing the
        // LP's variable/row structure. The hot path remaps the previous
        // basis onto the new structure, repairs feasibility with the
        // bounded dual simplex and warm-finishes; the baseline re-solves
        // the same new instance cold. Both sides pay the LP rebuild, so it
        // stays outside both windows.
        let mut churn_cold_s = 0.0;
        let mut churn_hot_s = 0.0;
        let mut repairs_ok = 0usize;
        let mut next_id: u64 = jobs.iter().map(|j| j.id).max().unwrap_or(0) + 1;
        let mut parked: Option<tesserae::policies::JobInfo> = None;
        for event in 0..CHURN_EVENTS {
            let old_ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
            let old_pairs = pairs.clone();
            if event % 2 == 0 {
                parked = Some(jobs.remove(jobs.len() / 2));
            } else {
                let mut j = parked.take().expect("departure precedes arrival");
                j.id = next_id;
                next_id += 1;
                j.attained_service = 0.0;
                jobs.push(j);
            }
            pairs = candidate_pairs(&jobs, true, PAIR_WINDOW);
            let mut new_lp = build_allocation_lp(&jobs, &pairs, TOTAL_GPUS);
            allocation_objective_into(
                GavelObjective::Las,
                &jobs,
                &pairs,
                source.as_ref(),
                &mut new_lp.objective,
            );

            let t0 = Instant::now();
            let (cold_sol, _) = solve_sparse_lp(&new_lp, None).expect("churn cold solve");
            churn_cold_s += t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let (var_map, row_map) = allocation_lp_maps(&old_ids, &old_pairs, &jobs, &pairs);
            let carried = warm.remapped(&var_map, &row_map, new_lp.num_vars(), new_lp.num_rows());
            let repaired = repair_warm_start(&new_lp, &carried);
            if repaired.is_some() {
                repairs_ok += 1;
            }
            let (hot_sol, next_warm) =
                solve_sparse_lp(&new_lp, repaired.as_ref()).expect("churn hot solve");
            churn_hot_s += t0.elapsed().as_secs_f64();

            assert!(
                (hot_sol.objective - cold_sol.objective).abs()
                    <= 1e-6 * (1.0 + cold_sol.objective.abs()),
                "{n} jobs, churn event {event}: repaired {} vs cold {}",
                hot_sol.objective,
                cold_sol.objective
            );
            warm = next_warm;
        }
        let churn_cold_avg_s = churn_cold_s / CHURN_EVENTS as f64;
        let churn_hot_avg_s = churn_hot_s / CHURN_EVENTS as f64;

        let speedup = dense_cold_s / warm_avg_s.max(1e-9);
        let repair_speedup = churn_cold_avg_s / churn_hot_avg_s.max(1e-9);
        if n == 1024 {
            speedup_at_1024 = Some(speedup);
            repair_speedup_at_1024 = Some(repair_speedup);
        }
        t.row(&[
            format!("{n}"),
            format!("{vars0}"),
            format!("{rows0}"),
            fmt_duration(dense_cold_s),
            fmt_duration(revised_cold_s),
            fmt_duration(warm_avg_s),
            format!("{speedup:.1}x"),
            fmt_duration(churn_cold_avg_s),
            fmt_duration(churn_hot_avg_s),
            format!("{repair_speedup:.1}x"),
        ]);
        cases.push(Json::obj(vec![
            ("jobs", Json::num(n as f64)),
            ("vars", Json::num(vars0 as f64)),
            ("rows", Json::num(rows0 as f64)),
            ("dense_cold_s", Json::num(dense_cold_s)),
            ("revised_cold_s", Json::num(revised_cold_s)),
            ("revised_warm_avg_s", Json::num(warm_avg_s)),
            ("warm_rounds", Json::num(WARM_ROUNDS as f64)),
            ("dense_objective", Json::num(dense.objective)),
            ("revised_objective", Json::num(rev_cold.objective)),
            ("cold_iterations", Json::num(rev_cold.iterations as f64)),
            (
                "warm_avg_iterations",
                Json::num(warm_iters as f64 / WARM_ROUNDS as f64),
            ),
            ("warm_vs_dense_speedup", Json::num(speedup)),
            ("churn_events", Json::num(CHURN_EVENTS as f64)),
            ("churn_cold_avg_s", Json::num(churn_cold_avg_s)),
            ("churn_repair_avg_s", Json::num(churn_hot_avg_s)),
            ("churn_repairs_ok", Json::num(repairs_ok as f64)),
            ("repair_vs_cold_speedup", Json::num(repair_speedup)),
        ]));
    }

    println!(
        "LP core: dense tableau vs sparse revised simplex (Gavel-shaped, {TOTAL_GPUS} GPUs)\n{}",
        t.render()
    );

    if smoke {
        println!("smoke mode: sizes reduced, acceptance asserts and JSON output skipped");
        return;
    }

    // Acceptance: warm-started round-over-round Gavel solves are ≥ 5x
    // faster than cold dense solves at 1024 jobs.
    if let Some(speedup) = speedup_at_1024 {
        assert!(
            speedup >= 5.0,
            "acceptance failed: warm revised only {speedup:.2}x vs cold dense at 1024 jobs"
        );
        println!("acceptance: warm revised {speedup:.1}x >= 5x vs cold dense at 1024 jobs");
    } else {
        println!("note: 1024-job case not in TESSERAE_BENCH_LP_SIZES; acceptance skipped");
    }

    // Acceptance (ISSUE 6): after a single-job arrival or departure, the
    // remap+repair+warm re-solve beats a cold sparse re-solve ≥ 3x at
    // 1024 jobs.
    if let Some(speedup) = repair_speedup_at_1024 {
        assert!(
            speedup >= 3.0,
            "acceptance failed: repair path only {speedup:.2}x vs cold sparse at 1024 jobs"
        );
        println!("acceptance: churn repair {speedup:.1}x >= 3x vs cold sparse at 1024 jobs");
    } else {
        println!("note: 1024-job case not in TESSERAE_BENCH_LP_SIZES; repair acceptance skipped");
    }

    let json = Json::obj(vec![
        ("bench", Json::str("lp")),
        ("meta", tesserae::util::benchutil::bench_meta()),
        ("total_gpus", Json::num(TOTAL_GPUS as f64)),
        ("cases", Json::arr(cases)),
    ]);
    match std::fs::write("BENCH_lp.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_lp.json"),
        Err(e) => println!("could not write BENCH_lp.json: {e}"),
    }
}

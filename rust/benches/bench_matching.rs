//! Matching-engine benchmark: native Hungarian vs native auction vs the
//! AOT JAX/Pallas auction executed through PJRT, across problem sizes.
//! Also times the rectangular fast path that the packing policy uses and
//! the arena "fill" kernels (bitset Hungarian, allocation-free auction)
//! against their allocating counterparts, with in-bench parity asserts.
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs tiny sizes on
//! the quick harness.

use tesserae::linalg::Matrix;
use tesserae::matching::auction::AuctionScratch;
use tesserae::matching::{auction, hungarian, MatchingEngine, SolveScratch};
use tesserae::util::benchutil::{smoke_mode, Bench};
use tesserae::util::rng::Pcg64;

fn random_cost(n: usize, m: usize, rng: &mut Pcg64) -> Matrix {
    let mut c = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            c.set(i, j, rng.below(64) as f64 / 16.0);
        }
    }
    c
}

fn main() {
    let smoke = smoke_mode();
    let mut bench = if smoke { Bench::quick() } else { Bench::new() };
    let mut rng = Pcg64::new(11);
    let squares: &[usize] = if smoke { &[8] } else { &[8, 32, 64, 128, 256] };
    let rects: &[(usize, usize)] = if smoke {
        &[(8, 16)]
    } else {
        &[(32, 256), (64, 512), (128, 1024)]
    };

    let mut scratch = SolveScratch::default();
    let mut auction_scratch = AuctionScratch::default();
    let mut auction_out: Vec<usize> = Vec::new();

    println!("== square assignment (migration-policy shape) ==");
    for &n in squares {
        let cost = random_cost(n, n, &mut rng);
        let exact = hungarian::solve_min_cost(&cost).cost;
        bench.run(&format!("hungarian n={n}"), || {
            hungarian::solve_min_cost(&cost).cost
        });
        // Arena kernel: identical totals, zero steady-state allocations.
        assert_eq!(
            hungarian::solve_min_cost_rect_fill(&cost, &mut scratch).1.to_bits(),
            exact.to_bits(),
            "fill kernel parity at n={n}"
        );
        bench.run(&format!("hungarian(fill) n={n}"), || {
            hungarian::solve_min_cost_rect_fill(&cost, &mut scratch).1
        });
        let cold = auction::solve_min_cost(&cost, Some(1.0 / 16.0)).cost;
        bench.run(&format!("auction(native) n={n}"), || {
            auction::solve_min_cost(&cost, Some(1.0 / 16.0)).cost
        });
        assert_eq!(
            auction::solve_min_cost_fill(
                &cost,
                Some(1.0 / 16.0),
                &mut auction_scratch,
                &mut auction_out,
            )
            .to_bits(),
            cold.to_bits(),
            "auction fill kernel parity at n={n}"
        );
        bench.run(&format!("auction(fill) n={n}"), || {
            auction::solve_min_cost_fill(
                &cost,
                Some(1.0 / 16.0),
                &mut auction_scratch,
                &mut auction_out,
            )
        });
    }

    println!("== rectangular assignment (packing-policy shape) ==");
    for &(n, m) in rects {
        let cost = random_cost(n, m, &mut rng);
        let exact = hungarian::solve_min_cost_rect(&cost).cost;
        bench.run(&format!("hungarian rect {n}x{m}"), || {
            hungarian::solve_min_cost_rect(&cost).cost
        });
        assert_eq!(
            hungarian::solve_min_cost_rect_fill(&cost, &mut scratch).1.to_bits(),
            exact.to_bits(),
            "rect fill kernel parity at {n}x{m}"
        );
        bench.run(&format!("hungarian(fill) rect {n}x{m}"), || {
            hungarian::solve_min_cost_rect_fill(&cost, &mut scratch).1
        });
    }

    // The AOT engine (skipped when artifacts are absent).
    match tesserae::runtime::AotAssignmentEngine::discover() {
        Ok(engine) => {
            println!("== AOT auction via PJRT (includes padding + channel hop) ==");
            for &n in squares {
                let cost = random_cost(n, n, &mut rng);
                let exact = hungarian::solve_min_cost(&cost).cost;
                let got = engine.solve_min_cost(&cost).cost;
                assert!((got - exact).abs() < 1e-3, "AOT mismatch at n={n}");
                bench.run(&format!("auction(AOT/PJRT) n={n}"), || {
                    engine.solve_min_cost(&cost).cost
                });
            }
        }
        Err(e) => println!("(AOT engine skipped: {e})"),
    }

    println!("\n{}", bench.report());
}

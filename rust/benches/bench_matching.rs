//! Matching-engine benchmark: native Hungarian vs native auction vs the
//! AOT JAX/Pallas auction executed through PJRT, across problem sizes.
//! Also times the rectangular fast path that the packing policy uses.

use tesserae::linalg::Matrix;
use tesserae::matching::{auction, hungarian, MatchingEngine};
use tesserae::util::benchutil::Bench;
use tesserae::util::rng::Pcg64;

fn random_cost(n: usize, m: usize, rng: &mut Pcg64) -> Matrix {
    let mut c = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            c.set(i, j, rng.below(64) as f64 / 16.0);
        }
    }
    c
}

fn main() {
    let mut bench = Bench::new();
    let mut rng = Pcg64::new(11);

    println!("== square assignment (migration-policy shape) ==");
    for n in [8usize, 32, 64, 128, 256] {
        let cost = random_cost(n, n, &mut rng);
        bench.run(&format!("hungarian n={n}"), || {
            hungarian::solve_min_cost(&cost).cost
        });
        bench.run(&format!("auction(native) n={n}"), || {
            auction::solve_min_cost(&cost, Some(1.0 / 16.0)).cost
        });
    }

    println!("== rectangular assignment (packing-policy shape) ==");
    for (n, m) in [(32usize, 256usize), (64, 512), (128, 1024)] {
        let cost = random_cost(n, m, &mut rng);
        bench.run(&format!("hungarian rect {n}x{m}"), || {
            hungarian::solve_min_cost_rect(&cost).cost
        });
    }

    // The AOT engine (skipped when artifacts are absent).
    match tesserae::runtime::AotAssignmentEngine::discover() {
        Ok(engine) => {
            println!("== AOT auction via PJRT (includes padding + channel hop) ==");
            for n in [8usize, 32, 64, 128, 256] {
                let cost = random_cost(n, n, &mut rng);
                let exact = hungarian::solve_min_cost(&cost).cost;
                let got = engine.solve_min_cost(&cost).cost;
                assert!((got - exact).abs() < 1e-3, "AOT mismatch at n={n}");
                bench.run(&format!("auction(AOT/PJRT) n={n}"), || {
                    engine.solve_min_cost(&cost).cost
                });
            }
        }
        Err(e) => println!("(AOT engine skipped: {e})"),
    }

    println!("\n{}", bench.report());
}

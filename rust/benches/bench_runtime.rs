//! PJRT runtime benchmarks: AOT artifact compile + execute latency per
//! assignment bucket, GP posterior latency, and train-step throughput
//! (the real-execution cluster's per-GPU compute rate).
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) shrinks sizes to one
//! bucket on the quick harness (still a no-op without built artifacts).

use tesserae::linalg::Matrix;
use tesserae::matching::MatchingEngine;
use tesserae::runtime::{AotAssignmentEngine, GpArtifact, Manifest, Runtime, TrainSession};
use tesserae::util::benchutil::{smoke_mode, Bench};
use tesserae::util::rng::Pcg64;

fn main() {
    let smoke = smoke_mode();
    let Ok(manifest) = Manifest::discover() else {
        println!("artifacts not built; run `make artifacts` first");
        return;
    };
    let mut bench = if smoke { Bench::quick() } else { Bench::new() };
    let mut rng = Pcg64::new(5);
    let sizes: &[usize] = if smoke { &[8] } else { &[8, 32, 64, 128, 256] };

    // Assignment artifact latency per bucket.
    let engine = AotAssignmentEngine::start(manifest.clone()).expect("engine");
    for &n in sizes {
        let mut cost = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                cost.set(i, j, rng.below(64) as f64 / 16.0);
            }
        }
        bench.run(&format!("aot assignment n={n}"), || {
            engine.solve_min_cost(&cost).cost
        });
    }

    // GP posterior latency.
    let rt = Runtime::new(manifest.clone()).expect("runtime");
    let gp = GpArtifact::load(&rt).expect("gp");
    let obs: Vec<(Vec<f64>, f64)> = (0..32)
        .map(|_| {
            let x: Vec<f64> = (0..7).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = x.iter().sum::<f64>();
            (x, y)
        })
        .collect();
    let queries: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..7).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();
    bench.run("aot gp posterior (32 obs, 64 queries)", || {
        gp.posterior(&obs, &queries).unwrap().len()
    });

    // Train-step throughput per model (the worker compute rate).
    for model in ["gpt-nano", "gpt-micro"] {
        let session = TrainSession::load(&rt, model).expect("session");
        let mut params = session.init_params(0).expect("init");
        let batch = session.synthetic_batch(&mut rng);
        let t = bench.run(&format!("train_step {model}"), || {
            session.step(&mut params, &batch).unwrap()
        });
        let tokens = session.spec.batch * session.spec.seq_len;
        println!(
            "{model}: {:.1} steps/s, {:.0} tokens/s ({} params)",
            1.0 / t.median_s,
            tokens as f64 / t.median_s,
            session.spec.num_params
        );
    }

    println!("\n{}", bench.report());
}

//! End-to-end scheduling benchmarks: Figs. 9, 11, 12, 13, 17 at the
//! standard bench scale (80 GPUs), plus the real-execution Fig. 3 /
//! Table 2 measurements when artifacts are present.
//!
//! Scale override: TESSERAE_BENCH_SCALE=quick|standard|paper

use tesserae::experiments::{end_to_end, Scale};

fn scale() -> Scale {
    match std::env::var("TESSERAE_BENCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::standard(),
    }
}

fn main() {
    let scale = scale();
    println!(
        "bench scale: {} jobs on {} GPUs\n",
        scale.jobs,
        scale.nodes * scale.gpus_per_node
    );
    let t0 = std::time::Instant::now();
    let (fig9, _, _) = end_to_end::fig9_tesserae_vs_tiresias(&scale);
    println!("{fig9}\n");
    println!("{}\n", end_to_end::fig11_vs_gavel(&scale));
    println!("{}\n", end_to_end::fig12_vs_tiresias_single(&scale));
    println!("{}\n", end_to_end::fig13_ftf(&scale));
    println!("{}\n", end_to_end::fig17_gavel_trace(&scale));
    println!("{}\n", tesserae::experiments::compatibility_study(&scale));
    println!("simulation figures took {:.1}s", t0.elapsed().as_secs_f64());

    // Real-execution measurements (need `make artifacts`).
    match end_to_end::fig3_real_migration_overhead(0.4) {
        Ok(s) => println!("\n{s}"),
        Err(e) => println!("\n(fig3 real-execution skipped: {e})"),
    }
    match end_to_end::table2_fidelity(2, 0.4) {
        Ok(s) => println!("\n{s}"),
        Err(e) => println!("\n(table2 fidelity skipped: {e})"),
    }
}

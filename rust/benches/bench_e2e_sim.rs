//! End-to-end scheduling benchmarks: Figs. 9, 11, 12, 13, 17 at the
//! standard bench scale (80 GPUs), plus the real-execution Fig. 3 /
//! Table 2 measurements when artifacts are present.
//!
//! Also records the simulator perf trajectory — rounds/sec and wall-clock
//! at 16- and 64-node scale, and the idle-gap-skipping speedup on a sparse
//! trace — into `BENCH_e2e_sim.json` so later PRs have a baseline to beat.
//!
//! Scale override: TESSERAE_BENCH_SCALE=quick|standard|paper
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs the quick scale
//! on one figure plus a tiny simulation, writing no JSON.

use std::sync::Arc;
use std::time::Instant;

use tesserae::cluster::{ClusterSpec, GpuType};
use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use tesserae::experiments::{build_scheduler, end_to_end, Scale, SchedKind};
use tesserae::jobs::{Job, ModelKind};
use tesserae::matching::HungarianEngine;
use tesserae::profiler::Profiler;
use tesserae::simulator::{simulate, SimConfig, SimResult};
use tesserae::trace::{Trace, TraceParams};
use tesserae::util::json::Json;

fn scale() -> Scale {
    match std::env::var("TESSERAE_BENCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::standard(),
    }
}

/// Run one simulation with an explicit gap-skip setting, returning the
/// result and the wall-clock seconds spent inside `simulate`.
fn timed_sim(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    skip_idle_gaps: bool,
) -> (SimResult, f64) {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth.clone())));
    let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
    let mut cfg = SimConfig::new(spec);
    cfg.skip_idle_gaps = skip_idle_gaps;
    let t0 = Instant::now();
    let r = simulate(trace, sched.as_mut(), &truth, &cfg);
    (r, t0.elapsed().as_secs_f64())
}

/// A deliberately sparse trace: short single-GPU jobs separated by long
/// idle gaps (`gap_rounds` 360 s rounds apart, each running ~`dur_rounds`
/// rounds) — the workload shape where the seed simulator burned thousands
/// of empty rounds spinning to the next arrival.
fn sparse_trace(num_jobs: usize, gap_rounds: u64, dur_rounds: u64) -> Trace {
    let round = 360.0;
    let model = ModelKind::ResNet50;
    let jobs = (0..num_jobs)
        .map(|i| Job {
            id: i as u64,
            model,
            num_gpus: 1,
            arrival_time: (i as u64 * gap_rounds) as f64 * round + 1.0,
            total_iters: dur_rounds as f64 * round * model.base_tput_a100() * 0.9,
            batch_size: 64,
        })
        .collect();
    Trace { jobs }
}

/// Perf trajectory: dense-trace rounds/sec at 16- and 64-node scale plus
/// the sparse-trace gap-skipping speedup. Returns (report, json).
fn perf_trajectory() -> (String, Json) {
    let mut report = String::from("Simulator perf trajectory (recorded in BENCH_e2e_sim.json)\n");
    let mut dense_entries = Vec::new();
    let mut sparse_entries = Vec::new();

    // Dense throughput: how many scheduler rounds per second the simulator
    // sustains end-to-end.
    let dense_cases = [(16usize, 4usize, 120usize, 80.0), (64, 8, 160, 120.0)];
    for (nodes, gpus_per_node, jobs, rate) in dense_cases {
        let spec = ClusterSpec::new(nodes, gpus_per_node, GpuType::A100);
        let trace = Trace::shockwave(&TraceParams {
            num_jobs: jobs,
            jobs_per_hour: rate,
            seed: 7,
        });
        let (r, wall) = timed_sim(SchedKind::TesseraeT, &trace, spec, 7, true);
        let rps = r.rounds as f64 / wall.max(1e-9);
        report.push_str(&format!(
            "  dense {nodes}x{gpus_per_node} ({} jobs): {} rounds in {:.2}s = {:.0} rounds/s, avg JCT {:.0}s\n",
            jobs, r.rounds, wall, rps, r.avg_jct
        ));
        dense_entries.push(Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("gpus_per_node", Json::num(gpus_per_node as f64)),
            ("jobs", Json::num(jobs as f64)),
            ("scheduler", Json::str("tesserae-t")),
            ("rounds", Json::num(r.rounds as f64)),
            ("wall_s", Json::num(wall)),
            ("rounds_per_sec", Json::num(rps)),
            ("avg_jct_s", Json::num(r.avg_jct)),
            ("total_migrations", Json::num(r.total_migrations as f64)),
        ]));
    }

    // Sparse gap skipping: identical metrics, wall-clock ratio is the win.
    let trace = sparse_trace(50, 200, 3);
    for (name, kind) in [
        ("tiresias", SchedKind::Tiresias),
        ("tesserae-t", SchedKind::TesseraeT),
    ] {
        let spec = ClusterSpec::new(64, 8, GpuType::A100);
        let (r_skip, wall_skip) = timed_sim(kind, &trace, spec, 7, true);
        let (r_spin, wall_spin) = timed_sim(kind, &trace, spec, 7, false);
        assert_eq!(r_skip.avg_jct.to_bits(), r_spin.avg_jct.to_bits());
        assert_eq!(r_skip.total_migrations, r_spin.total_migrations);
        let speedup = wall_spin / wall_skip.max(1e-9);
        report.push_str(&format!(
            "  sparse 64x8 {name}: skip {:.3}s vs spin {:.3}s = {:.1}x ({} rounds, {} busy)\n",
            wall_skip,
            wall_spin,
            speedup,
            r_skip.rounds,
            r_skip.timings.len()
        ));
        sparse_entries.push(Json::obj(vec![
            ("nodes", Json::num(64.0)),
            ("gpus_per_node", Json::num(8.0)),
            ("scheduler", Json::str(name)),
            ("rounds", Json::num(r_skip.rounds as f64)),
            ("busy_rounds", Json::num(r_skip.timings.len() as f64)),
            ("wall_skip_s", Json::num(wall_skip)),
            ("wall_spin_s", Json::num(wall_spin)),
            ("speedup", Json::num(speedup)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("e2e_sim")),
        ("meta", tesserae::util::benchutil::bench_meta()),
        ("dense", Json::arr(dense_entries)),
        ("sparse_gap_skip", Json::arr(sparse_entries)),
    ]);
    (report, json)
}

fn main() {
    if tesserae::util::benchutil::smoke_mode() {
        let scale = Scale::quick();
        let (fig9, _, _) = end_to_end::fig9_tesserae_vs_tiresias(&scale);
        println!("{fig9}\n");
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let trace = Trace::shockwave(&TraceParams {
            num_jobs: 8,
            jobs_per_hour: 40.0,
            seed: 7,
        });
        let (r, wall) = timed_sim(SchedKind::TesseraeT, &trace, spec, 7, true);
        println!(
            "smoke sim: {} rounds in {:.2}s, avg JCT {:.0}s — no JSON written",
            r.rounds, wall, r.avg_jct
        );
        return;
    }
    let scale = scale();
    println!(
        "bench scale: {} jobs on {} GPUs\n",
        scale.jobs,
        scale.nodes * scale.gpus_per_node
    );
    let t0 = std::time::Instant::now();
    let (fig9, _, _) = end_to_end::fig9_tesserae_vs_tiresias(&scale);
    println!("{fig9}\n");
    println!("{}\n", end_to_end::fig11_vs_gavel(&scale));
    println!("{}\n", end_to_end::fig12_vs_tiresias_single(&scale));
    println!("{}\n", end_to_end::fig13_ftf(&scale));
    println!("{}\n", end_to_end::fig17_gavel_trace(&scale));
    println!("{}\n", tesserae::experiments::compatibility_study(&scale));
    println!("simulation figures took {:.1}s", t0.elapsed().as_secs_f64());

    let (report, json) = perf_trajectory();
    println!("\n{report}");
    match std::fs::write("BENCH_e2e_sim.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_e2e_sim.json"),
        Err(e) => println!("could not write BENCH_e2e_sim.json: {e}"),
    }

    // Real-execution measurements (need `make artifacts`).
    match end_to_end::fig3_real_migration_overhead(0.4) {
        Ok(s) => println!("\n{s}"),
        Err(e) => println!("\n(fig3 real-execution skipped: {e})"),
    }
    match end_to_end::table2_fidelity(2, 0.4) {
        Ok(s) => println!("\n{s}"),
        Err(e) => println!("\n(table2 fidelity skipped: {e})"),
    }
}

//! Round-pipeline benchmark (ISSUE 4 acceptance artifact): consecutive
//! churned scheduling rounds driven through the staged pipeline with the
//! shared worker pool at budget 1 (sequential reference) vs the full
//! budget (sharded), at 32/64-node scale for Tesserae-T (matching batches,
//! packing-edge and strategy generation shard) and POP-8 (partition LP
//! solves shard). Decisions are asserted bit-identical between the two
//! budgets; emits `BENCH_round_pipeline.json` with per-config wall times
//! and speedups. Acceptance: the best 64-node arm must reach ≥1.5x.

use std::sync::Arc;
use std::time::Instant;

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
use tesserae::experiments::{build_scheduler, SchedKind};
use tesserae::matching::HungarianEngine;
use tesserae::profiler::Profiler;
use tesserae::schedulers::RoundInput;
use tesserae::util::json::Json;
use tesserae::util::pool::WorkerPool;

const ROUNDS: u64 = 4;

/// Drive `ROUNDS` consecutive decisions (fresh scheduler, ~15% job churn
/// per round so caches see realistic steady state) and return the total
/// wall plus every round's realized plan for the parity assert.
fn run_rounds(
    kind: SchedKind,
    n_jobs: usize,
    spec: &ClusterSpec,
    seed: u64,
) -> (f64, Vec<PlacementPlan>) {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth)));
    let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
    let mut active = synthetic_active_jobs(n_jobs, seed);
    let mut prev = PlacementPlan::new(spec.total_gpus());
    let mut plans = Vec::with_capacity(ROUNDS as usize);
    let t0 = Instant::now();
    for round in 0..ROUNDS {
        let d = sched.decide(&RoundInput {
            now: 1e6 + round as f64 * 360.0,
            round,
            active: &active,
            prev_plan: &prev,
            spec,
        });
        prev = d.plan.clone();
        plans.push(d.plan);
        active = churn_active_jobs(&active, seed ^ (round + 1));
    }
    (t0.elapsed().as_secs_f64(), plans)
}

fn main() {
    let pool = WorkerPool::global();
    let budget = pool.budget();
    let mut entries = Vec::new();
    let mut best64 = 0.0f64;
    println!("== Staged round pipeline: sequential (budget 1) vs sharded (budget {budget}) ==");
    println!("   ({ROUNDS} churned consecutive rounds per arm; plans asserted bit-identical)");
    for (nodes, kind, name) in [
        (32usize, SchedKind::TesseraeT, "tesserae-t"),
        (64, SchedKind::TesseraeT, "tesserae-t"),
        (32, SchedKind::Pop(8), "pop-8"),
        (64, SchedKind::Pop(8), "pop-8"),
    ] {
        let spec = ClusterSpec::new(nodes, 8, GpuType::A100);
        // Contended cluster: 2 jobs per GPU keeps the packing edge space,
        // the busy node-pair matchings and the POP partition LPs large.
        let n_jobs = spec.total_gpus() * 2;
        let seed = 42 + nodes as u64;
        let (seq_s, seq_plans) = {
            let _sequential = pool.budget_override(1);
            run_rounds(kind, n_jobs, &spec, seed)
        };
        let (par_s, par_plans) = run_rounds(kind, n_jobs, &spec, seed);
        assert_eq!(
            seq_plans, par_plans,
            "{name}@{nodes}: sharded decisions diverged from sequential"
        );
        let speedup = seq_s / par_s.max(1e-12);
        println!(
            "{name:>10} {nodes:>3}x8 ({n_jobs:>4} jobs): sharded {:>9.3}ms vs sequential \
             {:>9.3}ms = {speedup:>5.2}x per {ROUNDS} rounds",
            par_s * 1e3,
            seq_s * 1e3,
        );
        if nodes == 64 {
            best64 = best64.max(speedup);
        }
        entries.push(Json::obj(vec![
            ("scheduler", Json::str(name)),
            ("nodes", Json::num(nodes as f64)),
            ("gpus_per_node", Json::num(8.0)),
            ("jobs", Json::num(n_jobs as f64)),
            ("rounds", Json::num(ROUNDS as f64)),
            ("thread_budget", Json::num(budget as f64)),
            ("sequential_s", Json::num(seq_s)),
            ("sharded_s", Json::num(par_s)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    assert!(
        best64 >= 1.5,
        "acceptance: best 64-node sharded speedup {best64:.2}x < 1.5x"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("round_pipeline")),
        ("entries", Json::arr(entries)),
    ]);
    match std::fs::write("BENCH_round_pipeline.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_round_pipeline.json"),
        Err(e) => println!("could not write BENCH_round_pipeline.json: {e}"),
    }
}

//! Round-pipeline benchmark (ISSUE 4 acceptance artifact): consecutive
//! churned scheduling rounds driven through the staged pipeline with the
//! shared worker pool at budget 1 (sequential reference) vs the full
//! budget (sharded), at 32/64-node scale for Tesserae-T (matching batches,
//! packing-edge and strategy generation shard) and POP-8 (partition LP
//! solves shard). Decisions are asserted bit-identical between the two
//! budgets; emits `BENCH_round_pipeline.json` with per-config wall times
//! and speedups. Acceptance: the best 64-node arm must reach ≥1.5x.
//!
//! Allocation audit (ISSUE 6): when built with `--features alloc_audit`
//! the counting global allocator is installed, and this bench additionally
//! asserts that *steady-state* rounds (round ≥ 1, arenas grown to size)
//! perform **zero heap allocations inside matching solve kernels** — the
//! per-thread-measured `kernel_allocs` counter of every steady round must
//! be 0. Whole-round allocation counts are reported alongside for
//! context (rounds as a whole do allocate: plans, result handoff, LP
//! solves; the zero claim is scoped to the matching kernels).
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs a tiny config,
//! skips the speedup acceptance assert and writes no JSON.

use std::sync::Arc;
use std::time::Instant;

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
use tesserae::experiments::{build_scheduler, SchedKind};
use tesserae::matching::HungarianEngine;
use tesserae::profiler::Profiler;
use tesserae::schedulers::RoundInput;
use tesserae::obs;
use tesserae::util::alloc;
use tesserae::util::benchutil::{bench_meta, smoke_mode};
use tesserae::util::json::Json;
use tesserae::util::pool::WorkerPool;

const ROUNDS: u64 = 4;

/// Drive `ROUNDS` consecutive decisions (fresh scheduler, ~15% job churn
/// per round so caches see realistic steady state) and return the total
/// wall, every round's realized plan for the parity assert, and each
/// round's (matching-kernel allocations, whole-round allocations) pair
/// from the counting allocator (all zeros unless `alloc_audit` is on).
fn run_rounds(
    kind: SchedKind,
    n_jobs: usize,
    spec: &ClusterSpec,
    seed: u64,
) -> (f64, Vec<PlacementPlan>, Vec<(usize, usize)>) {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth)));
    let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
    let mut active = synthetic_active_jobs(n_jobs, seed);
    let mut prev = PlacementPlan::new(spec.total_gpus());
    let mut plans = Vec::with_capacity(ROUNDS as usize);
    let mut allocs = Vec::with_capacity(ROUNDS as usize);
    let t0 = Instant::now();
    for round in 0..ROUNDS {
        let round_alloc0 = alloc::allocs();
        let d = sched.decide(&RoundInput {
            now: 1e6 + round as f64 * 360.0,
            round,
            active: &active,
            prev_plan: &prev,
            spec,
            health: None,
        });
        allocs.push((d.timings.matching.kernel_allocs, alloc::allocs() - round_alloc0));
        prev = d.plan.clone();
        plans.push(d.plan);
        active = churn_active_jobs(&active, seed ^ (round + 1));
    }
    (t0.elapsed().as_secs_f64(), plans, allocs)
}

fn main() {
    let smoke = smoke_mode();
    let pool = WorkerPool::global();
    let budget = pool.budget();
    let mut entries = Vec::new();
    let mut best64 = 0.0f64;
    println!("== Staged round pipeline: sequential (budget 1) vs sharded (budget {budget}) ==");
    println!("   ({ROUNDS} churned consecutive rounds per arm; plans asserted bit-identical)");
    if alloc::audit_enabled() {
        println!("   (alloc_audit on: steady-state matching kernels asserted allocation-free)");
    }
    let configs: Vec<(usize, SchedKind, &str)> = if smoke {
        vec![(4, SchedKind::TesseraeT, "tesserae-t")]
    } else {
        vec![
            (32, SchedKind::TesseraeT, "tesserae-t"),
            (64, SchedKind::TesseraeT, "tesserae-t"),
            (32, SchedKind::Pop(8), "pop-8"),
            (64, SchedKind::Pop(8), "pop-8"),
        ]
    };
    for (nodes, kind, name) in configs {
        let spec = ClusterSpec::new(nodes, 8, GpuType::A100);
        // Contended cluster: 2 jobs per GPU keeps the packing edge space,
        // the busy node-pair matchings and the POP partition LPs large.
        let n_jobs = spec.total_gpus() * 2;
        let seed = 42 + nodes as u64;
        let (seq_s, seq_plans, _) = {
            let _sequential = pool.budget_override(1);
            run_rounds(kind, n_jobs, &spec, seed)
        };
        let (par_s, par_plans, par_allocs) = run_rounds(kind, n_jobs, &spec, seed);
        assert_eq!(
            seq_plans, par_plans,
            "{name}@{nodes}: sharded decisions diverged from sequential"
        );
        let speedup = seq_s / par_s.max(1e-12);
        println!(
            "{name:>10} {nodes:>3}x8 ({n_jobs:>4} jobs): sharded {:>9.3}ms vs sequential \
             {:>9.3}ms = {speedup:>5.2}x per {ROUNDS} rounds",
            par_s * 1e3,
            seq_s * 1e3,
        );
        if alloc::audit_enabled() {
            for (round, &(kernel, whole)) in par_allocs.iter().enumerate() {
                println!(
                    "{name:>10} {nodes:>3}x8 round {round}: {kernel} kernel allocs, \
                     {whole} whole-round allocs"
                );
                // Round 0 grows the arenas; every later round must run its
                // matching kernels without touching the heap.
                assert!(
                    round == 0 || kernel == 0,
                    "{name}@{nodes} round {round}: matching kernels made {kernel} heap \
                     allocations in steady state"
                );
            }
        }
        if nodes == 64 {
            best64 = best64.max(speedup);
        }
        let steady_kernel_allocs: usize =
            par_allocs.iter().skip(1).map(|&(k, _)| k).sum();
        entries.push(Json::obj(vec![
            ("scheduler", Json::str(name)),
            ("nodes", Json::num(nodes as f64)),
            ("gpus_per_node", Json::num(8.0)),
            ("jobs", Json::num(n_jobs as f64)),
            ("rounds", Json::num(ROUNDS as f64)),
            ("thread_budget", Json::num(budget as f64)),
            ("sequential_s", Json::num(seq_s)),
            ("sharded_s", Json::num(par_s)),
            ("speedup", Json::num(speedup)),
            ("alloc_audit", Json::Bool(alloc::audit_enabled())),
            ("steady_kernel_allocs", Json::num(steady_kernel_allocs as f64)),
            (
                "whole_round_allocs",
                Json::arr(
                    par_allocs.iter().map(|&(_, w)| Json::num(w as f64)).collect(),
                ),
            ),
        ]));
    }
    // Telemetry arm (ISSUE 7): the same config measured three ways —
    // plain (telemetry off), off again (the "disabled overhead" pair:
    // both arms run identical code with the gate cold, so their min-of-N
    // ratio bounds what the disabled gate can possibly cost), and on
    // (spans + metrics recording). Plans must be bit-identical across all
    // three; that is the determinism contract.
    let (t_nodes, t_kind, t_name) = if smoke {
        (4usize, SchedKind::TesseraeT, "tesserae-t")
    } else {
        (64usize, SchedKind::TesseraeT, "tesserae-t")
    };
    let t_spec = ClusterSpec::new(t_nodes, 8, GpuType::A100);
    let t_jobs = t_spec.total_gpus() * 2;
    let t_seed = 42 + t_nodes as u64;
    let reps = if smoke { 1 } else { 3 };
    println!("== Telemetry arm: {t_name}@{t_nodes}x8, {reps} rep(s) per mode ==");

    let measure = |reps: usize| {
        let mut best = f64::INFINITY;
        let mut plans = Vec::new();
        for _ in 0..reps {
            let (s, p, _) = run_rounds(t_kind, t_jobs, &t_spec, t_seed);
            best = best.min(s);
            plans = p;
        }
        (best, plans)
    };
    let (plain_s, plain_plans) = measure(reps);
    let (off_s, off_plans) = measure(reps);
    assert_eq!(
        plain_plans, off_plans,
        "telemetry arm: identical disabled runs diverged"
    );

    obs::metrics::reset();
    obs::recorder::clear();
    let spans_before = obs::span::recorded_total();
    obs::set_enabled(true);
    let (on_s, on_plans) = measure(reps);
    obs::set_enabled(false);
    let spans_recorded = obs::span::recorded_total() - spans_before;
    let snapshot = obs::metrics::snapshot();
    let flight_rounds = obs::recorder::rounds_recorded();

    if on_plans != plain_plans {
        obs::recorder::dump_on_failure("bench_round_pipeline telemetry parity");
        panic!("telemetry arm: plans with telemetry ON diverged from telemetry OFF");
    }
    for metric in [
        "round.total_s",
        "round.estimate_s",
        "round.schedule_s",
        "round.pack_s",
        "round.migrate_s",
        "round.commit_s",
    ] {
        assert!(
            snapshot.histograms.contains_key(metric),
            "telemetry arm: metric '{metric}' missing from snapshot"
        );
    }
    assert!(spans_recorded > 0, "telemetry arm recorded no spans");
    assert!(flight_rounds > 0, "flight recorder held no rounds");
    let disabled_overhead = off_s / plain_s.max(1e-12);
    let enabled_overhead = on_s / plain_s.max(1e-12);
    println!(
        "   telemetry: {spans_recorded} spans, {} metric series, {flight_rounds} rounds \
         in flight recorder",
        snapshot.series_count()
    );
    println!(
        "   disabled overhead {disabled_overhead:.3}x ({:.3}ms vs {:.3}ms), \
         enabled {enabled_overhead:.3}x ({:.3}ms)",
        off_s * 1e3,
        plain_s * 1e3,
        on_s * 1e3
    );

    if smoke {
        println!("smoke mode: tiny config, acceptance assert and JSON output skipped");
        return;
    }
    assert!(
        disabled_overhead <= 1.02,
        "acceptance: disabled-telemetry overhead {disabled_overhead:.3}x > 1.02x"
    );
    assert!(
        enabled_overhead <= 2.0,
        "enabled-telemetry overhead {enabled_overhead:.3}x is wildly out of budget"
    );
    assert!(
        best64 >= 1.5,
        "acceptance: best 64-node sharded speedup {best64:.2}x < 1.5x"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("round_pipeline")),
        ("meta", bench_meta()),
        ("entries", Json::arr(entries)),
        (
            "telemetry",
            Json::obj(vec![
                ("scheduler", Json::str(t_name)),
                ("nodes", Json::num(t_nodes as f64)),
                ("jobs", Json::num(t_jobs as f64)),
                ("reps", Json::num(reps as f64)),
                ("plain_s", Json::num(plain_s)),
                ("disabled_s", Json::num(off_s)),
                ("enabled_s", Json::num(on_s)),
                ("disabled_overhead", Json::num(disabled_overhead)),
                ("enabled_overhead", Json::num(enabled_overhead)),
                ("spans_recorded", Json::num(spans_recorded as f64)),
                ("metric_series", Json::num(snapshot.series_count() as f64)),
                ("flight_rounds", Json::num(flight_rounds as f64)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_round_pipeline.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_round_pipeline.json"),
        Err(e) => println!("could not write BENCH_round_pipeline.json: {e}"),
    }
}

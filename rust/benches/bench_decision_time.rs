//! Fig. 2 / Fig. 14: scheduler decision time vs active jobs on a 256-GPU
//! cluster at the paper's job counts (2048+), plus Tesserae-T's overhead
//! breakdown and the matching-engine comparison.
//!
//! Both sweeps checkpoint per cell (`BENCH_fig2_checkpoint.json` /
//! `BENCH_fig14b_checkpoint.json`): a budget-capped or interrupted run
//! keeps every completed measurement, and re-running resumes from the
//! files instead of re-measuring. Delete the files for a fresh sweep.
//!
//! Budget override: TESSERAE_FIG2_BUDGET_SECS (default 60).
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs tiny job counts
//! with no checkpoint files.

use std::time::Duration;

use tesserae::experiments::scalability::{self, FIG2_PAPER_JOB_COUNTS};
use tesserae::util::benchutil::{bench_meta, smoke_mode};
use tesserae::util::checkpoint::Checkpoint;

fn main() {
    if smoke_mode() {
        println!(
            "{}",
            scalability::fig2_decision_time_checkpointed(&[16], Duration::from_secs(5), None)
        );
        println!("{}", scalability::fig14b_breakdown_checkpointed(&[16], None));
        println!("{}", scalability::matching_engine_comparison(&[8], false));
        println!("smoke mode: tiny sweeps, no checkpoint files written");
        return;
    }
    let budget = Duration::from_secs(
        std::env::var("TESSERAE_FIG2_BUDGET_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(60),
    );
    let mut fig2_ckpt = Checkpoint::load_or_new("BENCH_fig2_checkpoint.json");
    if !fig2_ckpt.is_empty() {
        println!(
            "resuming fig2 from {} cells in {}",
            fig2_ckpt.len(),
            fig2_ckpt.path().display()
        );
    }
    // Provenance cell: which build/machine produced (or resumed) the sweep.
    // The cell key is never read as a measurement, so it can't collide
    // with the fig2/fig14b cell validation.
    if let Err(e) = fig2_ckpt.put("meta", bench_meta()) {
        tesserae::obs_log!(warn, "fig2 checkpoint meta write failed: {e}");
    }
    println!(
        "{}",
        scalability::fig2_decision_time_checkpointed(
            &FIG2_PAPER_JOB_COUNTS,
            budget,
            Some(&mut fig2_ckpt),
        )
    );
    let mut fig14_ckpt = Checkpoint::load_or_new("BENCH_fig14b_checkpoint.json");
    if let Err(e) = fig14_ckpt.put("meta", bench_meta()) {
        tesserae::obs_log!(warn, "fig14b checkpoint meta write failed: {e}");
    }
    println!(
        "{}",
        scalability::fig14b_breakdown_checkpointed(
            &[250, 500, 1000, 2048],
            Some(&mut fig14_ckpt),
        )
    );
    println!(
        "{}",
        scalability::matching_engine_comparison(&[16, 64, 128, 256], true)
    );
}

//! Fig. 2 / Fig. 14: scheduler decision time vs active jobs on a 256-GPU
//! cluster, plus Tesserae-T's overhead breakdown and the matching-engine
//! comparison.

use std::time::Duration;

use tesserae::experiments::scalability;

fn main() {
    let budget = Duration::from_secs(
        std::env::var("TESSERAE_FIG2_BUDGET_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(60),
    );
    println!(
        "{}",
        scalability::fig2_decision_time(&[250, 500, 1000, 2000, 3000], budget)
    );
    println!("{}", scalability::fig14b_breakdown(&[250, 500, 1000, 2000]));
    println!(
        "{}",
        scalability::matching_engine_comparison(&[16, 64, 128, 256], true)
    );
}

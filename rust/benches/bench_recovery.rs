//! Crash-recovery benchmarks: the cost and the correctness of the ISSUE 10
//! robustness layers, asserted inline.
//!
//! Emits `BENCH_recovery.json` with three arms:
//!
//!  * `snapshot_overhead` — the same run with and without every-round
//!    snapshots; asserts the decisions stay bit-identical and the wall
//!    overhead stays under 5% of round time (best-of-3 per arm to shed
//!    scheduler-noise outliers);
//!  * `restore_parity` — kill at a mid-run round, restore from the latest
//!    snapshot, assert the finished run is bit-identical to the
//!    uninterrupted one (per-job JCTs and migration counts included);
//!  * `deadline_recovery` — a stage that overruns its watchdog budget for
//!    two consecutive rounds trips the circuit breaker; asserts the run
//!    recovers within the breaker cooldown (fallback rounds + one clean
//!    probe) and drains every job.
//!
//! Scale override: TESSERAE_BENCH_SCALE=quick|standard|paper
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs one quick-scale
//! kill-and-restore parity check, writing no JSON.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tesserae::cluster::GpuType;
use tesserae::estimator::OracleEstimator;
use tesserae::experiments::{run_sim_recoverable, Scale, SchedKind};
use tesserae::matching::HungarianEngine;
use tesserae::profiler::Profiler;
use tesserae::recovery::{watchdog, BreakerConfig, BreakerScheduler, BreakerState};
use tesserae::schedulers::{
    run_round, RoundContext, RoundDecision, RoundInput, Scheduler, StageProvider,
    TesseraeScheduler,
};
use tesserae::simulator::{simulate, RecoveryOptions, SimConfig, SimResult};
use tesserae::util::json::Json;

fn scale() -> Scale {
    match std::env::var("TESSERAE_BENCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::standard(),
    }
}

fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tesserae-bench-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bit_parity(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits(), "{label}: avg JCT");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{label}: makespan");
    assert_eq!(a.total_migrations, b.total_migrations, "{label}: migrations");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcomes");
    for (id, oa) in &a.outcomes {
        assert_eq!(
            oa.jct.to_bits(),
            b.outcomes[id].jct.to_bits(),
            "{label}: job {id} JCT"
        );
        assert_eq!(oa.migrations, b.outcomes[id].migrations, "{label}: job {id}");
    }
}

/// Best-of-3 wall time for one recoverable run (the minimum is the least
/// noise-contaminated sample on a shared machine).
fn timed_run(
    kind: SchedKind,
    trace: &tesserae::trace::Trace,
    spec: tesserae::cluster::ClusterSpec,
    seed: u64,
    recovery: &RecoveryOptions,
) -> (SimResult, f64) {
    let mut best = f64::INFINITY;
    let mut result = None;
    for _ in 0..3 {
        if let Some(dir) = &recovery.state_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
        let t0 = Instant::now();
        let r = run_sim_recoverable(kind, trace, spec, seed, 0.0, recovery);
        best = best.min(t0.elapsed().as_secs_f64());
        result = Some(r);
    }
    (result.unwrap(), best)
}

fn snapshot_overhead_arm(scale: &Scale, cells: &mut Vec<Json>) {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let kind = SchedKind::TesseraeT;
    let (base, base_s) = timed_run(kind, &trace, spec, scale.seed, &RecoveryOptions::default());
    let dir = state_dir("overhead");
    let (snap, snap_s) = timed_run(
        kind,
        &trace,
        spec,
        scale.seed,
        &RecoveryOptions {
            state_dir: Some(dir.clone()),
            snapshot_every: 1,
            restore: false,
            stop_after_round: None,
        },
    );
    // Snapshots are write-only: every-round snapshotting must not perturb
    // a single decision.
    assert_bit_parity(&base, &snap, "snapshot-overhead");
    let per_round_base = base_s / base.rounds as f64;
    let per_round_snap = snap_s / snap.rounds as f64;
    let overhead = (per_round_snap - per_round_base).max(0.0) / per_round_base;
    assert!(
        overhead < 0.05,
        "every-round snapshots cost {:.1}% of round time (>= 5%): \
         {per_round_base:.6}s -> {per_round_snap:.6}s per round",
        overhead * 100.0
    );
    println!(
        "snapshot overhead: {:.2}% of round time ({} rounds, {:.4}s -> {:.4}s)",
        overhead * 100.0,
        base.rounds,
        base_s,
        snap_s
    );
    cells.push(Json::obj(vec![
        ("arm", Json::str("snapshot_overhead")),
        ("scheduler", Json::str(&kind.label())),
        ("rounds", Json::num(base.rounds as f64)),
        ("base_s", Json::num(base_s)),
        ("snapshot_every_round_s", Json::num(snap_s)),
        ("overhead_frac", Json::num(overhead)),
    ]));
    let _ = std::fs::remove_dir_all(&dir);
}

fn restore_parity_arm(scale: &Scale, kind: SchedKind, kill_round: u64, cells: &mut Vec<Json>) {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let reference =
        run_sim_recoverable(kind, &trace, spec, scale.seed, 0.0, &RecoveryOptions::default());
    assert_eq!(reference.unfinished, 0, "{kind:?}: reference must drain");
    let dir = state_dir(&format!("parity-{}", kind.label().replace('/', "-")));
    let killed = run_sim_recoverable(
        kind,
        &trace,
        spec,
        scale.seed,
        0.0,
        &RecoveryOptions {
            state_dir: Some(dir.clone()),
            snapshot_every: 1,
            restore: false,
            stop_after_round: Some(kill_round),
        },
    );
    assert!(
        killed.rounds < reference.rounds,
        "{kind:?}: kill at round {kill_round} must interrupt"
    );
    let t0 = Instant::now();
    let resumed = run_sim_recoverable(
        kind,
        &trace,
        spec,
        scale.seed,
        0.0,
        &RecoveryOptions {
            state_dir: Some(dir.clone()),
            snapshot_every: 1,
            restore: true,
            stop_after_round: None,
        },
    );
    let resume_s = t0.elapsed().as_secs_f64();
    assert_bit_parity(&reference, &resumed, &format!("restore {kind:?}"));
    println!(
        "restore parity ok: {} killed@{kill_round}, resumed {} rounds in {resume_s:.3}s",
        resumed.scheduler,
        resumed.rounds - killed.rounds
    );
    cells.push(Json::obj(vec![
        ("arm", Json::str("restore_parity")),
        ("scheduler", Json::str(&kind.label())),
        ("kill_round", Json::num(kill_round as f64)),
        ("rounds", Json::num(reference.rounds as f64)),
        ("resume_s", Json::num(resume_s)),
        ("bit_identical", Json::Bool(true)),
    ]));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tesserae-T whose `pack` stage sleeps past the armed watchdog budget
/// during `slow_rounds` — a deterministic stand-in for a hung kernel.
struct SlowPack {
    inner: TesseraeScheduler,
    slow_rounds: std::ops::Range<u64>,
}

impl StageProvider for SlowPack {
    fn estimate(&mut self, cx: &mut RoundContext) {
        self.inner.estimate(cx);
    }
    fn schedule(&mut self, cx: &mut RoundContext) {
        self.inner.schedule(cx);
    }
    fn pack(&mut self, cx: &mut RoundContext) {
        if self.slow_rounds.contains(&cx.input.round) {
            std::thread::sleep(Duration::from_millis(400));
        }
        self.inner.pack(cx);
    }
    fn migrate(&mut self, cx: &mut RoundContext) {
        self.inner.migrate(cx);
    }
    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        self.inner.commit(cx)
    }
    fn reset_after_failure(&mut self) {
        self.inner.reset_after_failure();
    }
}

impl Scheduler for SlowPack {
    fn name(&self) -> String {
        "slow-pack".into()
    }
    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        run_round(self, input)
    }
}

fn deadline_recovery_arm(cells: &mut Vec<Json>) {
    // Small fixed scenario: the arm measures the state machine, not
    // throughput, and the injected sleeps dominate its wall time anyway.
    let scale = Scale::quick();
    let trace = scale.shockwave_trace();
    let cfg = SimConfig::new(scale.spec(GpuType::A100));
    let truth = Profiler::new(GpuType::A100, scale.seed);
    let breaker_cfg = BreakerConfig {
        trip_after: 2,
        cooldown_rounds: 3,
    };
    watchdog::set_stage_deadline_ms(Some(100));
    let mut sched = BreakerScheduler::new(
        Box::new(SlowPack {
            inner: TesseraeScheduler::tesserae_t(
                Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, scale.seed))),
                Arc::new(HungarianEngine),
            ),
            slow_rounds: 2..4,
        }),
        breaker_cfg,
    );
    let r = simulate(&trace, &mut sched, &truth, &cfg);
    watchdog::set_stage_deadline_ms(None);

    assert_eq!(r.unfinished, 0, "deadline-tripped run must drain");
    assert_eq!(r.degraded_rounds, 2, "both overrun rounds must degrade");
    assert_eq!(sched.breaker().trips(), 1, "a streak of 2 must trip once");
    // Recovery within the cooldown window: after the trip at round 3 the
    // fallback serves rounds 4..7 and the round-7 probe closes the
    // breaker — so by trip + cooldown + 1 the real provider is back.
    assert_eq!(
        sched.breaker().state(),
        BreakerState::Closed,
        "the clean probe must close the breaker within the cooldown window"
    );
    println!(
        "deadline recovery ok: {} degraded rounds, {} trip(s), closed after \
         {}-round cooldown + probe",
        r.degraded_rounds,
        sched.breaker().trips(),
        breaker_cfg.cooldown_rounds
    );
    cells.push(Json::obj(vec![
        ("arm", Json::str("deadline_recovery")),
        ("stage_deadline_ms", Json::num(100.0)),
        ("trip_after", Json::num(breaker_cfg.trip_after as f64)),
        ("cooldown_rounds", Json::num(breaker_cfg.cooldown_rounds as f64)),
        ("degraded_rounds", Json::num(r.degraded_rounds as f64)),
        ("breaker_trips", Json::num(sched.breaker().trips() as f64)),
        ("recovered_within_cooldown", Json::Bool(true)),
    ]));
}

fn main() {
    if tesserae::util::benchutil::smoke_mode() {
        let scale = Scale::quick();
        let mut cells = Vec::new();
        restore_parity_arm(&scale, SchedKind::TesseraeT, 4, &mut cells);
        println!("smoke: kill-and-restore parity ok — no JSON written");
        return;
    }

    let scale = scale();
    println!(
        "bench scale: {} jobs on {} GPUs\n",
        scale.jobs,
        scale.nodes * scale.gpus_per_node
    );

    let mut cells = Vec::new();
    snapshot_overhead_arm(&scale, &mut cells);
    restore_parity_arm(&scale, SchedKind::TesseraeT, 5, &mut cells);
    restore_parity_arm(&scale, SchedKind::Sharded(4), 5, &mut cells);
    deadline_recovery_arm(&mut cells);

    let json = Json::obj(vec![
        ("bench", Json::str("recovery")),
        ("meta", tesserae::util::benchutil::bench_meta()),
        ("cells", Json::arr(cells)),
    ]);
    match std::fs::write("BENCH_recovery.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_recovery.json"),
        Err(e) => println!("could not write BENCH_recovery.json: {e}"),
    }
}

//! Fault-injection benchmarks: the robustness counterpart to
//! `bench_e2e_sim`.
//!
//! Emits `BENCH_faults.json` with the fault-matrix rows (MTBF sweep ×
//! Tesserae-T / Gavel / POP) — avg JCT, worst FTF, migrations, evictions,
//! preemptions, replacements, stragglers and degraded rounds per cell —
//! and asserts two contracts inline:
//!
//!  * rate 0 is bit-parity: a run with `FaultPlan::none()` reproduces the
//!    plain simulator decisions exactly, for all three scheduler families;
//!  * at the "paper" fault rate every job still completes and the JCT
//!    degradation stays bounded (< 3x the fault-free JCT).
//!
//! Everything is deterministic per seed; the same seeds always produce the
//! same JSON.
//!
//! Scale override: TESSERAE_BENCH_SCALE=quick|standard|paper
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs the parity
//! check plus one faulted cell at quick scale, writing no JSON.

use tesserae::cluster::GpuType;
use tesserae::experiments::faults::{fault_scenarios, run_fault_matrix, run_sim_faulted};
use tesserae::experiments::{run_sim, Scale, SchedKind};
use tesserae::faults::FaultPlan;
use tesserae::simulator::SimResult;
use tesserae::util::json::Json;

fn scale() -> Scale {
    match std::env::var("TESSERAE_BENCH_SCALE").as_deref() {
        Ok("quick") => Scale::quick(),
        Ok("paper") => Scale::paper(),
        _ => Scale::standard(),
    }
}

/// Rate-0 bit-parity: `FaultPlan::none()` through the fault path must be
/// indistinguishable from the plain simulator, decision for decision.
fn assert_rate_zero_parity(scale: &Scale) {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(4)] {
        let plain = run_sim(kind, &trace, spec, scale.seed, 0.0);
        let faulted = run_sim_faulted(kind, &trace, spec, scale.seed, &FaultPlan::none());
        assert_eq!(
            plain.avg_jct.to_bits(),
            faulted.avg_jct.to_bits(),
            "{}: rate-0 JCT parity broken",
            plain.scheduler
        );
        assert_eq!(plain.makespan.to_bits(), faulted.makespan.to_bits());
        assert_eq!(plain.total_migrations, faulted.total_migrations);
        assert_eq!(plain.rounds, faulted.rounds);
        assert_eq!(faulted.evictions + faulted.preemptions + faulted.stragglers, 0);
        assert_eq!(faulted.degraded_rounds, 0);
        for (id, a) in &plain.outcomes {
            assert_eq!(a.jct.to_bits(), faulted.outcomes[id].jct.to_bits());
            assert_eq!(a.migrations, faulted.outcomes[id].migrations);
        }
        println!(
            "  rate-0 parity ok: {} ({} rounds, avg JCT {:.0}s)",
            plain.scheduler, plain.rounds, plain.avg_jct
        );
    }
}

fn cell_json(scenario: &str, kind: SchedKind, r: &SimResult) -> Json {
    Json::obj(vec![
        ("scenario", Json::str(scenario)),
        ("scheduler", Json::str(&kind.label())),
        ("avg_jct_s", Json::num(r.avg_jct)),
        ("makespan_s", Json::num(r.makespan)),
        ("worst_ftf", Json::num(r.worst_ftf())),
        ("rounds", Json::num(r.rounds as f64)),
        ("total_migrations", Json::num(r.total_migrations as f64)),
        ("evictions", Json::num(r.evictions as f64)),
        ("preemptions", Json::num(r.preemptions as f64)),
        ("replacements", Json::num(r.replacements as f64)),
        ("stragglers", Json::num(r.stragglers as f64)),
        ("degraded_rounds", Json::num(r.degraded_rounds as f64)),
        ("infeasible_pairs", Json::num(r.infeasible_pairs as f64)),
        ("unfinished", Json::num(r.unfinished as f64)),
    ])
}

fn main() {
    if tesserae::util::benchutil::smoke_mode() {
        let scale = Scale::quick();
        println!("rate-0 bit-parity (quick scale):");
        assert_rate_zero_parity(&scale);
        // One faulted cell proves the fault path end-to-end.
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let scenarios = fault_scenarios(&spec, 100_000);
        let (label, plan) = &scenarios[2]; // "paper"
        let r = run_sim_faulted(SchedKind::TesseraeT, &trace, spec, scale.seed, plan);
        assert_eq!(r.unfinished, 0, "faulted smoke run must drain");
        println!(
            "smoke cell [{label}]: {} events -> evictions={} preemptions={} \
             replacements={} stragglers={} degraded={} avg JCT {:.0}s — no JSON written",
            plan.len(),
            r.evictions,
            r.preemptions,
            r.replacements,
            r.stragglers,
            r.degraded_rounds,
            r.avg_jct
        );
        return;
    }

    let scale = scale();
    println!(
        "bench scale: {} jobs on {} GPUs\n",
        scale.jobs,
        scale.nodes * scale.gpus_per_node
    );

    println!("rate-0 bit-parity:");
    assert_rate_zero_parity(&scale);
    println!();

    println!("{}\n", tesserae::experiments::faults::fault_matrix(&scale));

    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let kinds = [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(4)];
    let scenarios = fault_scenarios(&spec, 100_000);
    let t0 = std::time::Instant::now();
    let results = run_fault_matrix(&kinds, &scenarios, &trace, spec, scale.seed);
    let wall = t0.elapsed().as_secs_f64();

    // Determinism per seed: rerun one faulted cell and compare bits.
    let paper_idx = 2 * kinds.len(); // first scheduler of the "paper" row
    let redo = run_sim_faulted(
        kinds[0],
        &trace,
        spec,
        scale.seed,
        &scenarios[2].1,
    );
    assert_eq!(
        results[paper_idx].avg_jct.to_bits(),
        redo.avg_jct.to_bits(),
        "faulted runs must be deterministic per seed"
    );
    assert_eq!(results[paper_idx].evictions, redo.evictions);

    let mut cells = Vec::new();
    for (si, (label, _)) in scenarios.iter().enumerate() {
        for (ki, &kind) in kinds.iter().enumerate() {
            let r = &results[si * kinds.len() + ki];
            // Bounded degradation: at paper-scale fault rates the cluster
            // must still drain, and JCT must stay within 3x of fault-free.
            if si > 0 {
                let base = &results[ki];
                assert_eq!(
                    r.unfinished, 0,
                    "{} under '{label}' left jobs unfinished",
                    r.scheduler
                );
                assert!(
                    r.avg_jct <= 3.0 * base.avg_jct,
                    "{} under '{label}': avg JCT {:.0}s vs fault-free {:.0}s",
                    r.scheduler,
                    r.avg_jct,
                    base.avg_jct
                );
            }
            cells.push(cell_json(label, kind, r));
        }
    }
    println!("matrix: {} cells in {wall:.1}s", results.len());

    let json = Json::obj(vec![
        ("bench", Json::str("faults")),
        ("meta", tesserae::util::benchutil::bench_meta()),
        ("cells", Json::arr(cells)),
    ]);
    match std::fs::write("BENCH_faults.json", json.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_faults.json"),
        Err(e) => println!("could not write BENCH_faults.json: {e}"),
    }
}

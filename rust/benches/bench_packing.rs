//! Packing benchmarks: Fig. 8 (strategy-aware packing throughput), Fig. 15
//! (strategy impact on LLM JCT) and micro-timings of Algorithm 4 itself.
//!
//! Smoke mode: `--smoke` (or TESSERAE_BENCH_SMOKE=1) runs only a tiny
//! Algorithm 4 micro-timing on the quick harness.

use std::collections::BTreeSet;

use tesserae::cluster::GpuType;
use tesserae::estimator::{CachedSource, OracleEstimator};
use tesserae::experiments::{ablations, Scale};
use tesserae::jobs::ModelKind;
use tesserae::matching::HungarianEngine;
use tesserae::policies::placement::{pack, PackingConfig};
use tesserae::policies::JobInfo;
use tesserae::profiler::Profiler;
use tesserae::util::benchutil::Bench;
use tesserae::util::rng::Pcg64;

fn jobs(n: usize, seed: u64) -> Vec<JobInfo> {
    let mut rng = Pcg64::new(seed);
    let models = [
        ModelKind::ResNet50,
        ModelKind::Vgg19,
        ModelKind::Dcgan,
        ModelKind::PointNet,
    ];
    (0..n)
        .map(|i| JobInfo {
            id: i as u64,
            model: models[rng.below(4) as usize],
            num_gpus: [1u32, 1, 2, 4][rng.below(4) as usize],
            arrival_time: i as f64,
            attained_service: 0.0,
            total_iters: 1000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 0.0,
            iso_tput: 10.0,
        })
        .collect()
}

fn main() {
    let smoke = tesserae::util::benchutil::smoke_mode();
    if !smoke {
        println!("{}", ablations::fig8_parallelism_packing());
        let scale = Scale::standard();
        println!("{}", ablations::fig15_strategy_impact(&scale));
        println!(
            "{}",
            ablations::ablation_pack_threshold(&scale, &[0.5, 0.8, 1.0, 1.2])
        );
    }

    // Algorithm 4 micro-benchmark.
    let mut bench = if smoke { Bench::quick() } else { Bench::new() };
    let sizes: &[usize] = if smoke { &[16] } else { &[64, 256, 1024] };
    let source = CachedSource::new(OracleEstimator::new(Profiler::new(GpuType::A100, 3)));
    for &n in sizes {
        let all = jobs(2 * n, n as u64);
        let placed: Vec<&JobInfo> = all[..n].iter().collect();
        let pending: Vec<&JobInfo> = all[n..].iter().collect();
        let cfg = PackingConfig {
            exempt: BTreeSet::new(),
            ..Default::default()
        };
        bench.run(&format!("pack {n} placed x {n} pending"), || {
            pack(&placed, &pending, &source, &cfg, &HungarianEngine).len()
        });
    }
    println!("{}", bench.report());
}

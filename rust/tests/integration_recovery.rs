//! End-to-end watchdog + circuit-breaker scenario in its own test binary:
//! the `TESSERAE_STAGE_DEADLINE_MS` env knob and the process-global CLI
//! setter are shared state, so this file holds exactly one test — no
//! concurrent test in this process can race the deadline configuration.
//! (Pure state-machine tests live in `recovery::breaker`'s unit tests;
//! explicit-budget watchdog tests in `recovery::watchdog`'s.)

use std::sync::Arc;
use std::time::Duration;

use tesserae::cluster::{ClusterSpec, GpuType};
use tesserae::estimator::OracleEstimator;
use tesserae::matching::HungarianEngine;
use tesserae::obs::metrics;
use tesserae::profiler::Profiler;
use tesserae::recovery::watchdog::{self, DEADLINE_ENV};
use tesserae::recovery::{BreakerConfig, BreakerScheduler, BreakerState};
use tesserae::schedulers::{
    run_round, RoundContext, RoundDecision, RoundInput, Scheduler, StageProvider,
    TesseraeScheduler,
};
use tesserae::simulator::{simulate, SimConfig};
use tesserae::trace::{Trace, TraceParams};

/// Tesserae-T whose `pack` stage sleeps far past the armed budget during
/// `slow_rounds`, the way a hung matching kernel would — the guaranteed
/// per-stage checkpoint must trip the deadline.
struct SlowPack {
    inner: TesseraeScheduler,
    slow_rounds: std::ops::Range<u64>,
}

impl StageProvider for SlowPack {
    fn estimate(&mut self, cx: &mut RoundContext) {
        self.inner.estimate(cx);
    }
    fn schedule(&mut self, cx: &mut RoundContext) {
        self.inner.schedule(cx);
    }
    fn pack(&mut self, cx: &mut RoundContext) {
        if self.slow_rounds.contains(&cx.input.round) {
            std::thread::sleep(Duration::from_millis(400));
        }
        self.inner.pack(cx);
    }
    fn migrate(&mut self, cx: &mut RoundContext) {
        self.inner.migrate(cx);
    }
    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        self.inner.commit(cx)
    }
    fn reset_after_failure(&mut self) {
        self.inner.reset_after_failure();
    }
}

impl Scheduler for SlowPack {
    fn name(&self) -> String {
        "slow-pack".into()
    }
    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        run_round(self, input)
    }
}

/// The full robustness loop, driven by the env knob end to end: two
/// consecutive rounds overrun their stage budget → both degrade with the
/// `deadline` reason → the breaker trips → the greedy fallback serves the
/// cooldown → the half-open probe (stage fast again) closes the breaker —
/// and the run still drains every job, deterministically.
#[test]
fn deadline_overruns_trip_breaker_then_recover() {
    // Env fallback path: must be read before anything else in this
    // process touches the watchdog (the value is cached on first read).
    std::env::set_var(DEADLINE_ENV, "100");
    assert_eq!(
        watchdog::stage_deadline_ms(),
        Some(100),
        "env knob must configure the stage budget"
    );

    let trace = Trace::shockwave(&TraceParams {
        num_jobs: 12,
        jobs_per_hour: 240.0,
        seed: 41,
    });
    let truth = Profiler::new(GpuType::A100, 42);
    let cfg = SimConfig::new(ClusterSpec::new(2, 4, GpuType::A100));
    let build = || {
        BreakerScheduler::new(
            Box::new(SlowPack {
                inner: TesseraeScheduler::tesserae_t(
                    Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42))),
                    Arc::new(HungarianEngine),
                ),
                slow_rounds: 2..4,
            }),
            BreakerConfig {
                trip_after: 2,
                cooldown_rounds: 3,
            },
        )
    };

    // Telemetry on so the deadline/breaker counters record.
    let _g = tesserae::obs::enabled_guard(true);
    let base = metrics::snapshot();

    let mut sched = build();
    let r = simulate(&trace, &mut sched, &truth, &cfg);

    assert_eq!(r.unfinished, 0, "the run must recover and drain");
    assert!(r.rounds > 8, "run too short to exercise the probe: {}", r.rounds);
    // Rounds 2 and 3 trip the deadline; the trip at round 3 opens the
    // breaker for rounds 4..7, whose greedy fallback decisions are not
    // degraded; the round-7 probe is fast and closes it.
    assert_eq!(r.degraded_rounds, 2, "exactly the two overrun rounds degrade");
    assert_eq!(sched.breaker().trips(), 1, "streak of 2 must trip once");
    assert_eq!(
        sched.breaker().state(),
        BreakerState::Closed,
        "the clean probe must close the breaker"
    );

    let delta = metrics::snapshot().delta_since(&base);
    let counter = |k: &str| delta.counters.get(k).copied().unwrap_or(0);
    assert_eq!(counter("watchdog.deadline_trips"), 2);
    assert_eq!(counter("round.degraded_deadline"), 2);
    assert_eq!(counter("breaker.trips"), 1);
    assert_eq!(
        counter("breaker.fallback_rounds"),
        3,
        "cooldown_rounds=3 must serve exactly 3 fallback rounds"
    );

    // Deadline-degraded runs replay bit-identically: the trips depend
    // only on the injected sleeps, never on ambient timing.
    let mut sched2 = build();
    let r2 = simulate(&trace, &mut sched2, &truth, &cfg);
    assert_eq!(r.avg_jct.to_bits(), r2.avg_jct.to_bits());
    assert_eq!(r.total_migrations, r2.total_migrations);
    assert_eq!(r2.degraded_rounds, 2);
    assert_eq!(sched2.breaker().trips(), 1);

    // Disable via the CLI setter (takes precedence over the cached env
    // value) and prove a rerun no longer trips anything.
    watchdog::set_stage_deadline_ms(None);
    std::env::remove_var(DEADLINE_ENV);
    let mut sched3 = build();
    let r3 = simulate(&trace, &mut sched3, &truth, &cfg);
    assert_eq!(r3.degraded_rounds, 0, "disabled watchdog must not trip");
    assert_eq!(sched3.breaker().trips(), 0);
    assert_eq!(r3.unfinished, 0);
}

//! Integration tests over the PJRT runtime: the AOT artifacts must load,
//! execute, and agree with the native Rust oracles. Requires
//! `make artifacts` to have run (skips otherwise).

use tesserae::estimator::gp::Gp;
use tesserae::linalg::Matrix;
use tesserae::matching::{hungarian, MatchingEngine};
use tesserae::runtime::{AotAssignmentEngine, GpArtifact, Manifest, Runtime, TrainSession};
use tesserae::util::rng::Pcg64;

fn manifest() -> Option<Manifest> {
    Manifest::discover().ok()
}

#[test]
fn aot_assignment_matches_hungarian() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = AotAssignmentEngine::start(m).expect("start engine");
    let mut rng = Pcg64::new(7);
    for n in [3usize, 8, 13, 16, 40, 64] {
        let mut cost = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                // 1/16-quantized costs (the migration-cost resolution).
                cost.set(i, j, rng.below(64) as f64 / 16.0);
            }
        }
        let aot = engine.solve_min_cost(&cost);
        let exact = hungarian::solve_min_cost(&cost);
        assert!(
            (aot.cost - exact.cost).abs() < 1e-4,
            "n={n}: aot {} vs exact {}",
            aot.cost,
            exact.cost
        );
        // Must be a permutation of the real block.
        let mut seen = vec![false; n];
        for &c in &aot.row_to_col {
            assert!(c < n && !seen[c]);
            seen[c] = true;
        }
    }
}

#[test]
fn aot_assignment_solves_packing_shapes() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = AotAssignmentEngine::start(m).expect("start engine");
    // A max-weight matching reduction shape: forbidden edges + dummies.
    let edges = vec![(0usize, 0usize, 1.25f64), (0, 1, 0.5), (1, 1, 1.5)];
    let pairs = tesserae::matching::max_weight_matching(2, 2, &edges, &engine);
    let total: f64 = pairs.iter().map(|p| p.weight).sum();
    assert!((total - 2.75).abs() < 1e-3, "total {total}");
}

#[test]
fn gp_artifact_matches_native_gp() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::new(m).expect("runtime");
    let gp = GpArtifact::load(&rt).expect("load gp");
    assert_eq!(gp.dim, 7);

    let mut rng = Pcg64::new(3);
    let obs: Vec<(Vec<f64>, f64)> = (0..10)
        .map(|_| {
            let x: Vec<f64> = (0..7).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = x.iter().sum::<f64>() / 3.0;
            (x, y)
        })
        .collect();
    let queries: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..7).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();

    let aot = gp.posterior(&obs, &queries).expect("posterior");

    // Native GP with the same hyperparameters (0.6, 0.25, 1e-4).
    let native = Gp::fit(
        obs.iter().map(|(x, _)| x.clone()).collect(),
        &obs.iter().map(|(_, y)| *y).collect::<Vec<_>>(),
        0.6,
        0.25,
        1e-4,
    )
    .expect("fit native");
    for (q, (am, av)) in queries.iter().zip(&aot) {
        let (nm, nv) = native.predict(q);
        assert!((am - nm).abs() < 1e-3, "mean {am} vs {nm}");
        assert!((av - nv).abs() < 1e-3, "var {av} vs {nv}");
    }
}

#[test]
fn train_session_loss_decreases() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::new(m).expect("runtime");
    let session = TrainSession::load(&rt, "gpt-nano").expect("load model");
    assert!(session.spec.num_params > 50_000);
    let mut params = session.init_params(0).expect("init");
    assert_eq!(params.tensors.len(), session.spec.param_shapes.len());

    let mut rng = Pcg64::new(1);
    let mut losses = Vec::new();
    for _ in 0..30 {
        let batch = session.synthetic_batch(&mut rng);
        let loss = session.step(&mut params, &batch).expect("step");
        losses.push(loss as f64);
    }
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < first,
        "loss should descend: first {first} last {last} ({losses:?})"
    );
    assert!(first > 4.0, "initial loss ~ ln(V): {first}");
}

#[test]
fn param_average_is_elementwise_mean() {
    use tesserae::runtime::train::ParamState;
    let a = ParamState {
        tensors: vec![vec![1.0, 3.0], vec![2.0]],
    };
    let b = ParamState {
        tensors: vec![vec![3.0, 5.0], vec![4.0]],
    };
    let avg = ParamState::average(&[a, b]);
    assert_eq!(avg.tensors, vec![vec![2.0, 4.0], vec![3.0]]);
}

#[test]
fn full_simulation_on_aot_engine_matches_hungarian() {
    // Cross-layer end-to-end: run the complete scheduler+simulator stack
    // with every matching problem solved by the AOT JAX/Pallas auction via
    // PJRT, and compare against the native Hungarian run. Both engines are
    // exact on the migration costs; packing weights are floats so we allow
    // a small JCT tolerance.
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use std::sync::Arc;
    use tesserae::cluster::{ClusterSpec, GpuType};
    use tesserae::experiments::{run_sim_engine, SchedKind};
    use tesserae::trace::{Trace, TraceParams};

    let trace = Trace::shockwave(&TraceParams {
        num_jobs: 12,
        jobs_per_hour: 240.0,
        seed: 5,
    });
    let spec = ClusterSpec::new(2, 2, GpuType::A100);
    let aot_engine = Arc::new(AotAssignmentEngine::start(m).expect("engine"));
    let aot = run_sim_engine(SchedKind::TesseraeT, &trace, spec, 5, 0.0, aot_engine);
    let native = run_sim_engine(
        SchedKind::TesseraeT,
        &trace,
        spec,
        5,
        0.0,
        Arc::new(tesserae::matching::HungarianEngine),
    );
    assert_eq!(aot.unfinished, 0);
    assert_eq!(native.unfinished, 0);
    let rel = (aot.avg_jct - native.avg_jct).abs() / native.avg_jct;
    assert!(rel < 0.05, "aot {} vs native {}", aot.avg_jct, native.avg_jct);
}

#[test]
fn coordinator_trains_real_jobs_with_packing() {
    // Minimal real-execution run: 3 jobs on 2 workers forces packing; all
    // jobs must finish with descending loss and real checkpoint movement
    // accounting.
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use tesserae::coordinator::{run_cluster, ExecConfig, ExecJob};
    let jobs = vec![
        ExecJob {
            id: 1,
            model: "gpt-nano".into(),
            num_gpus: 1,
            arrival_round: 0,
            total_steps: 20,
        },
        ExecJob {
            id: 2,
            model: "gpt-nano".into(),
            num_gpus: 1,
            arrival_round: 0,
            total_steps: 20,
        },
        ExecJob {
            id: 3,
            model: "gpt-nano".into(),
            num_gpus: 1,
            arrival_round: 0,
            total_steps: 20,
        },
    ];
    let cfg = ExecConfig {
        num_nodes: 1,
        gpus_per_node: 2,
        round_wall_s: 0.3,
        seed: 2,
        ..Default::default()
    };
    let r = run_cluster(&jobs, &cfg).expect("run cluster");
    assert_eq!(r.jobs.len(), 3);
    for (id, j) in &r.jobs {
        assert!(j.steps >= 20, "job {id} underran: {} steps", j.steps);
        assert!(
            j.last_loss < j.first_loss,
            "job {id} loss did not descend"
        );
    }
    // 3 single-GPU jobs on 2 GPUs requires packing in round 0.
    assert!(r.rounds >= 1);
}

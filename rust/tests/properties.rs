//! Cross-module property tests: randomized placement plans, the
//! incremental job→GPU index against a from-scratch rebuild, migration
//! optimality relations, packing-matching validity, and simulator
//! conservation laws.

use std::collections::{BTreeMap, BTreeSet};

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::jobs::JobId;
use tesserae::matching::{
    max_weight_matching, AuctionEngine, HungarianEngine, MatchingEngine, MatchingService,
    ServiceConfig,
};
use tesserae::policies::placement::{migrate, migrate_with, MigrationMode};
use tesserae::util::prop::forall;
use tesserae::util::rng::Pcg64;

/// Generate a random valid placement plan: single- and multi-GPU jobs,
/// optional packing (≤ 2 tenants/GPU), consolidated multi-GPU jobs.
fn random_plan(spec: &ClusterSpec, rng: &mut Pcg64, job_base: u64) -> PlacementPlan {
    let mut plan = PlacementPlan::new(spec.total_gpus());
    let mut next_job = job_base;
    // First tenant layer.
    for node in 0..spec.num_nodes {
        let gpus: Vec<usize> = spec.gpus_of_node(node).collect();
        let mut i = 0;
        while i < gpus.len() {
            match rng.below(4) {
                0 => i += 1, // leave empty
                1 if i + 1 < gpus.len() => {
                    plan.place(next_job, &[gpus[i], gpus[i + 1]]);
                    next_job += 1;
                    i += 2;
                }
                _ => {
                    plan.place(next_job, &[gpus[i]]);
                    next_job += 1;
                    i += 1;
                }
            }
        }
    }
    // Second tenant layer: pack some 1-GPU jobs onto occupied GPUs.
    for g in 0..spec.total_gpus() {
        if plan.jobs_on(g).len() == 1 && rng.f64() < 0.3 {
            plan.place(next_job, &[g]);
            next_job += 1;
        }
    }
    plan
}

/// Keep a random subset of jobs from both plans as "common" so migration
/// has something to align.
fn overlay_common(
    prev: &mut PlacementPlan,
    next: &mut PlacementPlan,
    rng: &mut Pcg64,
) -> BTreeSet<JobId> {
    let prev_jobs: Vec<JobId> = prev.jobs().into_iter().collect();
    let next_jobs: Vec<JobId> = next.jobs().into_iter().collect();
    let mut common = BTreeSet::new();
    // Rename a random subset of next's jobs to match prev's ids where the
    // GPU-count matches (so both rounds contain them).
    for &nj in &next_jobs {
        if rng.f64() < 0.5 {
            let n_gpus = next.gpus_of(nj).len();
            if let Some(&pj) = prev_jobs.iter().find(|&&pj| {
                prev.gpus_of(pj).len() == n_gpus
                    && !common.contains(&pj)
                    && !next.jobs().contains(&pj)
            }) {
                let gpus = next.remove(nj);
                next.place(pj, &gpus);
                common.insert(pj);
            }
        }
    }
    common
}

/// One mutation of a [`PlacementPlan`], pre-validated by the generator so
/// the replay in the property never violates `place`'s preconditions.
#[derive(Debug, Clone)]
enum PlanOp {
    Place(JobId, Vec<usize>),
    Remove(JobId),
    RemoveJobs(Vec<JobId>),
    Relabel(Vec<usize>),
}

/// Apply one op to a plan (relabeling replaces the plan wholesale).
fn apply_op(plan: &mut PlacementPlan, op: &PlanOp) {
    match op {
        PlanOp::Place(job, gpus) => plan.place(*job, gpus),
        PlanOp::Remove(job) => {
            plan.remove(*job);
        }
        PlanOp::RemoveJobs(jobs) => {
            let set: BTreeSet<JobId> = jobs.iter().copied().collect();
            plan.remove_jobs(&set);
        }
        PlanOp::Relabel(perm) => *plan = plan.relabeled(perm),
    }
}

/// Generate a random but valid op sequence by simulating it on a scratch
/// plan (placements only target GPUs with free capacity, removals only
/// target present jobs).
fn gen_plan_ops(rng: &mut Pcg64) -> (usize, Vec<PlanOp>) {
    let total = 4 + rng.below(13) as usize; // 4..=16 GPUs
    let mut plan = PlacementPlan::new(total);
    let mut next_job: JobId = 0;
    let mut ops = Vec::new();
    for _ in 0..40 {
        match rng.below(10) {
            0..=4 => {
                let want = 1 + rng.below(4) as usize;
                let mut free: Vec<usize> =
                    (0..total).filter(|&g| plan.free_capacity(g) > 0).collect();
                if free.is_empty() {
                    continue;
                }
                rng.shuffle(&mut free);
                free.truncate(want.min(free.len()));
                let job = next_job;
                next_job += 1;
                plan.place(job, &free);
                ops.push(PlanOp::Place(job, free));
            }
            5..=6 => {
                let jobs: Vec<JobId> = plan.jobs().into_iter().collect();
                if jobs.is_empty() {
                    continue;
                }
                let job = jobs[rng.below(jobs.len() as u64) as usize];
                plan.remove(job);
                ops.push(PlanOp::Remove(job));
            }
            7..=8 => {
                let mut jobs: Vec<JobId> = plan.jobs().into_iter().collect();
                if jobs.is_empty() {
                    continue;
                }
                let k = 1 + rng.below(jobs.len() as u64) as usize;
                rng.shuffle(&mut jobs);
                jobs.truncate(k);
                let set: BTreeSet<JobId> = jobs.iter().copied().collect();
                plan.remove_jobs(&set);
                ops.push(PlanOp::RemoveJobs(jobs));
            }
            _ => {
                let mut perm: Vec<usize> = (0..total).collect();
                rng.shuffle(&mut perm);
                plan = plan.relabeled(&perm);
                ops.push(PlanOp::Relabel(perm));
            }
        }
    }
    (total, ops)
}

#[test]
fn incremental_index_always_matches_slot_rebuild() {
    // The tentpole invariant: under arbitrary place / remove / remove_jobs
    // / relabeled sequences, the incrementally maintained job→GPU index
    // equals a from-scratch rebuild of the slots view after every step.
    forall(
        "job->GPU index == slot rebuild",
        91,
        60,
        gen_plan_ops,
        |(total, ops)| {
            let mut plan = PlacementPlan::new(*total);
            for (step, op) in ops.iter().enumerate() {
                apply_op(&mut plan, op);
                // validate() cross-checks index vs slots internally...
                plan.validate()
                    .map_err(|e| format!("step {step} ({op:?}): {e}"))?;
                // ...and we rebuild independently for good measure.
                let mut rebuilt: BTreeMap<JobId, Vec<usize>> = BTreeMap::new();
                for g in 0..plan.num_gpus() {
                    for &j in plan.jobs_on(g) {
                        rebuilt.entry(j).or_default().push(g);
                    }
                }
                if &rebuilt != plan.job_gpu_map() {
                    return Err(format!(
                        "step {step} ({op:?}): index {:?} != rebuilt {rebuilt:?}",
                        plan.job_gpu_map()
                    ));
                }
                for (&job, gpus) in plan.job_gpu_map() {
                    if gpus.is_empty() {
                        return Err(format!("step {step}: job {job} indexed with no GPUs"));
                    }
                    if gpus.windows(2).any(|w| w[0] >= w[1]) {
                        return Err(format!(
                            "step {step}: job {job} GPU set not sorted: {gpus:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tesserae_migration_never_worse_than_baseline_random_plans() {
    forall(
        "migrations(tesserae) <= migrations(baseline)",
        101,
        60,
        |rng| {
            let spec = ClusterSpec::new(
                2 + rng.below(3) as usize,
                2 + rng.below(3) as usize * 2,
                GpuType::A100,
            );
            let mut prev = random_plan(&spec, rng, 0);
            let mut next = random_plan(&spec, rng, 1000);
            overlay_common(&mut prev, &mut next, rng);
            (spec, prev, next)
        },
        |(spec, prev, next)| {
            let ours = migrate(spec, prev, next, MigrationMode::Tesserae, &HungarianEngine);
            let base = migrate(spec, prev, next, MigrationMode::GavelBaseline, &HungarianEngine);
            ours.plan.validate().map_err(|e| e.to_string())?;
            if ours.migrations <= base.migrations {
                Ok(())
            } else {
                Err(format!("{} > {}", ours.migrations, base.migrations))
            }
        },
    );
}

#[test]
fn migration_preserves_job_shapes_and_tenancy() {
    forall(
        "relabeled plan preserves every job's footprint",
        103,
        60,
        |rng| {
            let spec = ClusterSpec::new(2 + rng.below(2) as usize, 4, GpuType::A100);
            let mut prev = random_plan(&spec, rng, 0);
            let mut next = random_plan(&spec, rng, 500);
            overlay_common(&mut prev, &mut next, rng);
            (spec, prev, next)
        },
        |(spec, prev, next)| {
            for mode in [MigrationMode::Tesserae, MigrationMode::Flat] {
                let out = migrate(spec, prev, next, mode, &HungarianEngine);
                if out.plan.jobs() != next.jobs() {
                    return Err(format!("{mode:?}: job set changed"));
                }
                for j in next.jobs() {
                    if out.plan.gpus_of(j).len() != next.gpus_of(j).len() {
                        return Err(format!("{mode:?}: job {j} footprint changed"));
                    }
                }
                // Co-tenancy must be preserved: jobs sharing a GPU in the
                // logical plan still share one physically.
                for g in 0..next.num_gpus() {
                    let tenants = next.jobs_on(g);
                    if tenants.len() == 2 {
                        let a = out.plan.gpus_of(tenants[0]);
                        let b = out.plan.gpus_of(tenants[1]);
                        if !a.iter().any(|g| b.contains(g)) {
                            return Err(format!("{mode:?}: packed pair split apart"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tesserae_migration_preserves_consolidation() {
    forall(
        "consolidated jobs stay consolidated",
        107,
        40,
        |rng| {
            let spec = ClusterSpec::new(3, 4, GpuType::A100);
            let mut prev = random_plan(&spec, rng, 0);
            let mut next = random_plan(&spec, rng, 500);
            overlay_common(&mut prev, &mut next, rng);
            (spec, prev, next)
        },
        |(spec, prev, next)| {
            let out = migrate(spec, prev, next, MigrationMode::Tesserae, &HungarianEngine);
            for j in out.plan.jobs() {
                if next.is_consolidated(j, spec) && !out.plan.is_consolidated(j, spec) {
                    return Err(format!("job {j} lost consolidation"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn matching_service_is_bit_identical_to_sequential_solves() {
    // ISSUE 2's parity acceptance: with pruning, dedup, caching and the
    // parallel pool all enabled, every migration outcome (plan, count,
    // cost) is bit-identical to per-instance sequential solves — across
    // random plans, both migration modes and both native engines.
    forall(
        "batched service == sequential reference",
        131,
        40,
        |rng| {
            let spec = ClusterSpec::new(
                2 + rng.below(4) as usize,
                2 + rng.below(3) as usize,
                GpuType::A100,
            );
            let mut prev = random_plan(&spec, rng, 0);
            let mut next = random_plan(&spec, rng, 1000);
            overlay_common(&mut prev, &mut next, rng);
            (spec, prev, next)
        },
        |(spec, prev, next)| {
            let auction = AuctionEngine::default();
            let engines: [&dyn MatchingEngine; 2] = [&HungarianEngine, &auction];
            for mode in [MigrationMode::Tesserae, MigrationMode::Flat] {
                for engine in engines {
                    let mut batched = MatchingService::new(ServiceConfig {
                        parallel_threshold: 1, // force the worker pool
                        ..Default::default()
                    });
                    let mut reference =
                        MatchingService::new(ServiceConfig::sequential_reference());
                    let a = migrate_with(spec, prev, next, mode, engine, &mut batched);
                    let b = migrate_with(spec, prev, next, mode, engine, &mut reference);
                    if a.plan != b.plan {
                        return Err(format!("{mode:?}/{}: plans diverged", engine.name()));
                    }
                    if a.migrations != b.migrations {
                        return Err(format!(
                            "{mode:?}/{}: migrations {} != {}",
                            engine.name(),
                            a.migrations,
                            b.migrations
                        ));
                    }
                    if a.cost.to_bits() != b.cost.to_bits() {
                        return Err(format!(
                            "{mode:?}/{}: cost {} != {}",
                            engine.name(),
                            a.cost,
                            b.cost
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn matching_service_cache_replay_matches_cold_rebuilds() {
    // Cross-round cache invalidation: one service carried across an
    // evolving round sequence must produce exactly what a cold service
    // produces per round; and replaying an identical round must resolve
    // every node-pair instance without a single new pair solve.
    forall(
        "warm cache replay == cold rebuild",
        137,
        20,
        |rng| {
            let spec = ClusterSpec::new(3, 2, GpuType::A100);
            let mut plans = vec![random_plan(&spec, rng, 0)];
            for r in 1..5u64 {
                // Evolve: drop a job, add a job, keep the rest in place —
                // the partial-churn shape whose unchanged node pairs the
                // cache should reuse.
                let mut p = plans[(r - 1) as usize].clone();
                let jobs: Vec<JobId> = p.jobs().into_iter().collect();
                if !jobs.is_empty() && rng.f64() < 0.7 {
                    p.remove(jobs[rng.below(jobs.len() as u64) as usize]);
                }
                if rng.f64() < 0.7 {
                    let empty = p.empty_gpus();
                    if !empty.is_empty() {
                        let g = empty[rng.below(empty.len() as u64) as usize];
                        p.place(10_000 * r + rng.below(10), &[g]);
                    }
                }
                plans.push(p);
            }
            (spec, plans)
        },
        |(spec, plans)| {
            let mut warm = MatchingService::with_defaults();
            for w in plans.windows(2) {
                let a = migrate_with(
                    spec,
                    &w[0],
                    &w[1],
                    MigrationMode::Tesserae,
                    &HungarianEngine,
                    &mut warm,
                );
                let b = migrate(spec, &w[0], &w[1], MigrationMode::Tesserae, &HungarianEngine);
                if a.plan != b.plan || a.migrations != b.migrations {
                    return Err("warm service diverged from cold rebuild".into());
                }
            }
            // Replay the last window twice more: after the first replay the
            // cache holds every pair content, so the second must solve only
            // the (uncacheable) node matrix.
            let (p, n) = (&plans[plans.len() - 2], &plans[plans.len() - 1]);
            let r1 = migrate_with(spec, p, n, MigrationMode::Tesserae, &HungarianEngine, &mut warm);
            let r2 = migrate_with(spec, p, n, MigrationMode::Tesserae, &HungarianEngine, &mut warm);
            if r1.plan != r2.plan {
                return Err("replayed round changed the outcome".into());
            }
            if r2.service.solved != 1 {
                return Err(format!(
                    "replay should only solve the node matrix: {:?}",
                    r2.service
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn matching_engines_agree_on_quantized_random_graphs() {
    forall(
        "hungarian == auction on random packing graphs",
        109,
        40,
        |rng| {
            let nl = 1 + rng.below(10) as usize;
            let nr = 1 + rng.below(10) as usize;
            let m = 1 + rng.below(24) as usize;
            let edges: Vec<(usize, usize, f64)> = (0..m)
                .map(|_| {
                    (
                        rng.below(nl as u64) as usize,
                        rng.below(nr as u64) as usize,
                        rng.below(64) as f64 / 16.0,
                    )
                })
                .collect();
            (nl, nr, edges)
        },
        |(nl, nr, edges)| {
            let h: f64 = max_weight_matching(*nl, *nr, edges, &HungarianEngine)
                .iter()
                .map(|p| p.weight)
                .sum();
            let a: f64 = max_weight_matching(
                *nl,
                *nr,
                edges,
                &AuctionEngine {
                    resolution: Some(1.0 / 16.0),
                },
            )
            .iter()
            .map(|p| p.weight)
            .sum();
            tesserae::util::prop::approx_eq(h, a, 1e-6)
        },
    );
}

#[test]
fn simulator_conserves_work() {
    // Conservation: every finished job received exactly its total work; no
    // job finishes before its arrival.
    use tesserae::experiments::{run_sim, SchedKind};
    use tesserae::trace::{Trace, TraceParams};

    forall(
        "work conservation",
        113,
        8,
        |rng| {
            let jobs = 10 + rng.below(20) as usize;
            Trace::shockwave(&TraceParams {
                num_jobs: jobs,
                jobs_per_hour: 200.0,
                seed: rng.next_u64(),
            })
        },
        |trace| {
            let spec = ClusterSpec::new(2, 4, GpuType::A100);
            let r = run_sim(SchedKind::TesseraeT, trace, spec, 1, 0.0);
            if r.unfinished != 0 {
                return Err(format!("{} unfinished", r.unfinished));
            }
            for (id, o) in &r.outcomes {
                if o.jct <= 0.0 {
                    return Err(format!("job {id} has non-positive JCT"));
                }
                if o.rounds_run == 0 {
                    return Err(format!("job {id} finished without running"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lp_allocation_never_exceeds_capacity() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator};
    use tesserae::experiments::scalability::synthetic_active_jobs;
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::{GavelObjective, GavelScheduler, RoundInput, Scheduler};

    forall(
        "gavel plan fits the cluster",
        127,
        12,
        |rng| {
            let spec = ClusterSpec::new(
                1 + rng.below(4) as usize,
                2 + rng.below(3) as usize,
                GpuType::A100,
            );
            let jobs = synthetic_active_jobs(5 + rng.below(40) as usize, rng.next_u64());
            (spec, jobs)
        },
        |(spec, jobs)| {
            let source = Arc::new(CachedSource::new(OracleEstimator::new(Profiler::new(
                GpuType::A100,
                7,
            ))));
            let mut sched = GavelScheduler::new(
                GavelObjective::Las,
                true,
                source,
                Arc::new(HungarianEngine),
            );
            let prev = PlacementPlan::new(spec.total_gpus());
            let d = sched.decide(&RoundInput {
                now: 0.0,
                round: 0,
                active: jobs,
                prev_plan: &prev,
                spec,
                health: None,
            });
            d.plan.validate().map_err(|e| e.to_string())
        },
    );
}

#[test]
fn revised_simplex_matches_dense_on_gavel_instances() {
    // The tentpole's parity contract at integration level: randomized
    // Gavel-shaped allocation LPs (mixed GPU demands, packing pairs,
    // degenerate capacity bindings, native 0≤x≤1 bounds) solved by the
    // sparse revised simplex must reach the same optimum as the retained
    // dense tableau solver run on the materialized instance — and the
    // revised solution must respect capacity, coupling rows and bounds.
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::synthetic_active_jobs;
    use tesserae::linalg::{solve_lp, solve_sparse_lp};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::gavel::{
        allocation_objective_into, build_allocation_lp, candidate_pairs,
    };
    use tesserae::schedulers::GavelObjective;

    let source: Arc<dyn ThroughputSource> = Arc::new(CachedSource::new(OracleEstimator::new(
        Profiler::new(GpuType::A100, 11),
    )));
    forall(
        "revised == dense on Gavel-shaped LPs",
        137,
        10,
        |rng| {
            let n = 4 + rng.below(36) as usize;
            let total_gpus = 4 + rng.below(64) as usize;
            let packing = rng.f64() < 0.8;
            let window = 1 + rng.below(6) as usize;
            let objective = if rng.f64() < 0.5 {
                GavelObjective::Las
            } else {
                GavelObjective::Ftf
            };
            (synthetic_active_jobs(n, rng.next_u64()), total_gpus, packing, window, objective)
        },
        |(jobs, total_gpus, packing, window, objective)| {
            let pairs = candidate_pairs(jobs, *packing, *window);
            let mut lp = build_allocation_lp(jobs, &pairs, *total_gpus);
            allocation_objective_into(
                *objective,
                jobs,
                &pairs,
                source.as_ref(),
                &mut lp.objective,
            );
            let (rev, warm) = solve_sparse_lp(&lp, None).map_err(|e| e.to_string())?;
            let dense = solve_lp(&lp.to_dense_lp()).map_err(|e| e.to_string())?;
            if (rev.objective - dense.objective).abs() > 1e-6 * (1.0 + dense.objective.abs()) {
                return Err(format!(
                    "objective diverges: revised {} vs dense {}",
                    rev.objective, dense.objective
                ));
            }
            // Feasibility of the revised solution against the sparse rows.
            let ax = lp.constraints.matvec(&rev.x);
            for (i, (&lhs, &b)) in ax.iter().zip(&lp.rhs).enumerate() {
                if lhs > b + 1e-6 {
                    return Err(format!("row {i} violated: {lhs} > {b}"));
                }
            }
            for (j, &x) in rev.x.iter().enumerate() {
                if !(-1e-9..=1.0 + 1e-9).contains(&x) {
                    return Err(format!("x[{j}] = {x} outside [0, 1]"));
                }
            }
            // Warm-started re-solve of the identical instance is a no-op
            // that lands on the same optimum.
            let (hot, _) = solve_sparse_lp(&lp, Some(&warm)).map_err(|e| e.to_string())?;
            if (hot.objective - rev.objective).abs() > 1e-9 * (1.0 + rev.objective.abs()) {
                return Err(format!(
                    "warm replay diverges: {} vs {}",
                    hot.objective, rev.objective
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn repaired_warm_starts_match_cold_and_dense_under_churn() {
    // ISSUE 6 tentpole contract at integration level: across randomized
    // Gavel windows, every arrival/departure step's remap + dual-simplex
    // repair + warm finish must land on the same optimum as a cold sparse
    // solve of the new window AND the dense tableau oracle, within 1e-6.
    // 30 cases × 4 churn steps = 120 churned rounds.
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::synthetic_active_jobs;
    use tesserae::linalg::{repair_warm_start, solve_lp, solve_sparse_lp};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::gavel::{
        allocation_lp_maps, allocation_objective_into, build_allocation_lp, candidate_pairs,
    };
    use tesserae::schedulers::GavelObjective;

    let source: Arc<dyn ThroughputSource> = Arc::new(CachedSource::new(OracleEstimator::new(
        Profiler::new(GpuType::A100, 19),
    )));
    forall(
        "repair == cold sparse == dense oracle under churn",
        139,
        30,
        |rng| {
            let n = 6 + rng.below(24) as usize;
            let total_gpus = 8 + rng.below(56) as usize;
            let window = 1 + rng.below(6) as usize;
            (synthetic_active_jobs(n, rng.next_u64()), total_gpus, window, rng.next_u64())
        },
        |(jobs0, total_gpus, window, seed)| {
            let mut jobs = jobs0.clone();
            let mut rng = Pcg64::new(*seed);
            let mut pairs = candidate_pairs(&jobs, true, *window);
            let mut lp = build_allocation_lp(&jobs, &pairs, *total_gpus);
            allocation_objective_into(
                GavelObjective::Las,
                &jobs,
                &pairs,
                source.as_ref(),
                &mut lp.objective,
            );
            let (_, mut warm) = solve_sparse_lp(&lp, None).map_err(|e| e.to_string())?;
            let mut next_id = jobs.iter().map(|j| j.id).max().unwrap_or(0) + 1;
            for step in 0..4usize {
                let old_ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
                let old_pairs = pairs.clone();
                if step % 2 == 0 && jobs.len() > 3 {
                    let k = rng.below(jobs.len() as u64) as usize;
                    jobs.remove(k);
                } else {
                    let mut j = jobs[rng.below(jobs.len() as u64) as usize].clone();
                    j.id = next_id;
                    next_id += 1;
                    j.attained_service = 0.0;
                    jobs.push(j);
                }
                pairs = candidate_pairs(&jobs, true, *window);
                lp = build_allocation_lp(&jobs, &pairs, *total_gpus);
                allocation_objective_into(
                    GavelObjective::Las,
                    &jobs,
                    &pairs,
                    source.as_ref(),
                    &mut lp.objective,
                );
                let (var_map, row_map) =
                    allocation_lp_maps(&old_ids, &old_pairs, &jobs, &pairs);
                let carried =
                    warm.remapped(&var_map, &row_map, lp.num_vars(), lp.num_rows());
                let repaired = repair_warm_start(&lp, &carried);
                let (hot, next_warm) =
                    solve_sparse_lp(&lp, repaired.as_ref()).map_err(|e| e.to_string())?;
                let (cold, _) = solve_sparse_lp(&lp, None).map_err(|e| e.to_string())?;
                let dense = solve_lp(&lp.to_dense_lp()).map_err(|e| e.to_string())?;
                if (hot.objective - cold.objective).abs()
                    > 1e-6 * (1.0 + cold.objective.abs())
                {
                    return Err(format!(
                        "step {step}: repaired {} vs cold sparse {}",
                        hot.objective, cold.objective
                    ));
                }
                if (hot.objective - dense.objective).abs()
                    > 1e-6 * (1.0 + dense.objective.abs())
                {
                    return Err(format!(
                        "step {step}: repaired {} vs dense oracle {}",
                        hot.objective, dense.objective
                    ));
                }
                warm = next_warm;
            }
            Ok(())
        },
    );
}

// ======================================================= round pipeline

/// The staged round pipeline's parity contract (ISSUE 4): for every
/// scheduler family, consecutive churned decisions under a worker-pool
/// budget of 1 (everything inline/sequential) are bit-identical to the
/// same decisions under a multi-thread budget — plans, strategies, packed
/// pairs and migration counts.
#[test]
fn staged_pipeline_is_bit_identical_across_pool_budgets() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
    use tesserae::experiments::{build_scheduler, SchedKind};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::RoundInput;
    use tesserae::util::pool::WorkerPool;

    let spec = ClusterSpec::new(6, 4, GpuType::A100);
    for seed in [3u64, 17, 91] {
        for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(3)] {
            let run = |budget: usize| {
                let _budget = WorkerPool::global().budget_override(budget);
                let truth = Profiler::new(spec.gpu_type, seed);
                let source: Arc<dyn ThroughputSource> =
                    Arc::new(CachedSource::new(OracleEstimator::new(truth)));
                let mut sched =
                    build_scheduler(kind, source, Arc::new(HungarianEngine));
                let mut active = synthetic_active_jobs(40, seed);
                let mut prev = PlacementPlan::new(spec.total_gpus());
                let mut decisions = Vec::new();
                for round in 0..3u64 {
                    let d = sched.decide(&RoundInput {
                        now: round as f64 * 360.0,
                        round,
                        active: &active,
                        prev_plan: &prev,
                        spec: &spec,
                        health: None,
                    });
                    prev = d.plan.clone();
                    decisions.push((d.plan, d.strategies, d.packed_pairs, d.migrations));
                    active = churn_active_jobs(&active, seed ^ (round + 7));
                }
                decisions
            };
            let sequential = run(1);
            let sharded = run(6);
            assert_eq!(sequential, sharded, "{kind:?} seed {seed}");
        }
    }
}

/// Replay of the pre-refactor monolithic `decide()` — priority order →
/// allocate → pack → migrate run inline from the public pieces — against
/// the staged pipeline, across churned rounds: realized plans, packed
/// pairs and migration counts must be bit-identical.
#[test]
fn staged_tesserae_matches_monolithic_replay() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
    use tesserae::policies::placement::{
        allocate_without_packing, pack_with, PackingConfig,
    };
    use tesserae::policies::scheduling::{SchedulingPolicy, TiresiasLas};
    use tesserae::policies::JobInfo;
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::{RoundInput, Scheduler, TesseraeScheduler};

    let spec = ClusterSpec::new(4, 4, GpuType::A100);
    for seed in [5u64, 23] {
        let truth = Profiler::new(spec.gpu_type, seed);
        let source: Arc<dyn ThroughputSource> =
            Arc::new(CachedSource::new(OracleEstimator::new(truth)));
        let engine = HungarianEngine;
        let mut staged =
            TesseraeScheduler::tesserae_t(Arc::clone(&source), Arc::new(HungarianEngine));
        // The monolithic replay keeps its own persistent service, exactly
        // as the pre-refactor scheduler did.
        let mut service = MatchingService::with_defaults();
        let policy = TiresiasLas::default();
        let mut active = synthetic_active_jobs(30, seed);
        let mut prev_staged = PlacementPlan::new(spec.total_gpus());
        let mut prev_mono = PlacementPlan::new(spec.total_gpus());
        for round in 0..4u64 {
            let d = staged.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev_staged,
                spec: &spec,
                health: None,
            });

            let order = policy.order(&active);
            let ordered: Vec<&JobInfo> = order.iter().map(|&i| &active[i]).collect();
            let alloc = allocate_without_packing(&spec, &ordered);
            let mut plan = alloc.plan;
            let by_id: std::collections::BTreeMap<_, _> =
                active.iter().map(|j| (j.id, j)).collect();
            let placed: Vec<&JobInfo> = alloc.placed.iter().map(|id| by_id[id]).collect();
            let pending: Vec<&JobInfo> = alloc.pending.iter().map(|id| by_id[id]).collect();
            let mut pairs = Vec::new();
            for p in pack_with(
                &placed,
                &pending,
                source.as_ref(),
                &PackingConfig::default(),
                &engine,
                &mut service,
            ) {
                let gpus = plan.gpus_of(p.placed).to_vec();
                plan.place(p.pending, &gpus);
                pairs.push((p.placed, p.pending));
            }
            let outcome = migrate_with(
                &spec,
                &prev_mono,
                &plan,
                MigrationMode::Tesserae,
                &engine,
                &mut service,
            );

            assert_eq!(d.plan, outcome.plan, "seed {seed} round {round}");
            assert_eq!(d.packed_pairs, pairs, "seed {seed} round {round}");
            assert_eq!(d.migrations, outcome.migrations, "seed {seed} round {round}");
            prev_staged = d.plan;
            prev_mono = outcome.plan;
            active = churn_active_jobs(&active, seed ^ (round + 11));
        }
    }
}

// ========================================================== telemetry

/// ISSUE 7's determinism contract: telemetry is write-only — spans,
/// metrics and the flight recorder are recorded on the decision path but
/// never read by it — so churned multi-round decision sequences must be
/// bit-identical with telemetry enabled vs disabled, for every scheduler
/// family (Tesserae matching/packing, Gavel's LP rounds, POP's recursive
/// sub-schedulers on pool workers).
#[test]
fn decisions_bit_identical_with_telemetry_on_and_off() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
    use tesserae::experiments::{build_scheduler, SchedKind};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::RoundInput;

    let spec = ClusterSpec::new(6, 4, GpuType::A100);
    for seed in [7u64, 29] {
        for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(3)] {
            let run = |telemetry: bool| {
                // The guard's global lock also serializes the two arms
                // against any other telemetry-toggling test in this binary.
                let _guard = tesserae::obs::enabled_guard(telemetry);
                let truth = Profiler::new(spec.gpu_type, seed);
                let source: Arc<dyn ThroughputSource> =
                    Arc::new(CachedSource::new(OracleEstimator::new(truth)));
                let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
                let mut active = synthetic_active_jobs(40, seed);
                let mut prev = PlacementPlan::new(spec.total_gpus());
                let mut decisions = Vec::new();
                for round in 0..4u64 {
                    let d = sched.decide(&RoundInput {
                        now: round as f64 * 360.0,
                        round,
                        active: &active,
                        prev_plan: &prev,
                        spec: &spec,
                        health: None,
                    });
                    prev = d.plan.clone();
                    decisions.push((d.plan, d.strategies, d.packed_pairs, d.migrations));
                    active = churn_active_jobs(&active, seed ^ (round + 13));
                }
                decisions
            };
            let off = run(false);
            let on = run(true);
            assert_eq!(
                off, on,
                "{kind:?} seed {seed}: enabling telemetry changed the decisions"
            );
        }
    }
}

// ============================================================== faults

/// Fault-rate-0 bit-parity (ISSUE 8): a fully healthy mask must be
/// indistinguishable from no mask at all. `RoundInput.health = None` is
/// the pre-fault code path; `Some(all-healthy)` walks the masked
/// allocator, blocker-aware matcher and health-sized LP — every family's
/// decisions must come out bit-identical either way.
#[test]
fn all_healthy_mask_is_bit_identical_to_no_mask() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
    use tesserae::experiments::{build_scheduler, SchedKind};
    use tesserae::faults::ClusterHealth;
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::RoundInput;

    let spec = ClusterSpec::new(6, 4, GpuType::A100);
    let healthy = ClusterHealth::new(spec.total_gpus());
    for seed in [11u64, 43] {
        for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(3)] {
            let run = |mask: Option<&ClusterHealth>| {
                let truth = Profiler::new(spec.gpu_type, seed);
                let source: Arc<dyn ThroughputSource> =
                    Arc::new(CachedSource::new(OracleEstimator::new(truth)));
                let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
                let mut active = synthetic_active_jobs(40, seed);
                let mut prev = PlacementPlan::new(spec.total_gpus());
                let mut decisions = Vec::new();
                for round in 0..3u64 {
                    let d = sched.decide(&RoundInput {
                        now: round as f64 * 360.0,
                        round,
                        active: &active,
                        prev_plan: &prev,
                        spec: &spec,
                        health: mask,
                    });
                    prev = d.plan.clone();
                    decisions.push((d.plan, d.strategies, d.packed_pairs, d.migrations));
                    active = churn_active_jobs(&active, seed ^ (round + 17));
                }
                decisions
            };
            let unmasked = run(None);
            let masked = run(Some(&healthy));
            assert_eq!(
                unmasked, masked,
                "{kind:?} seed {seed}: an all-healthy mask changed the decisions"
            );
        }
    }
}

/// When faults *do* fire — evictions, preemptions, stragglers, a dead
/// node's worth of masked GPUs — the whole simulation must stay
/// bit-identical across worker-pool thread budgets: per-job JCTs and
/// migration counts, plan-diff totals, and every fault counter.
#[test]
fn faulted_simulation_is_bit_identical_across_pool_budgets() {
    use tesserae::experiments::faults::run_sim_faulted;
    use tesserae::experiments::{Scale, SchedKind};
    use tesserae::faults::{FaultEvent, FaultKind, FaultPlan};
    use tesserae::util::pool::WorkerPool;

    let scale = Scale {
        jobs: 14,
        nodes: 2,
        gpus_per_node: 4,
        jobs_per_hour: 240.0,
        seed: 5,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let faults = FaultPlan::from_events(vec![
        FaultEvent { round: 1, kind: FaultKind::GpuFail(2) },
        FaultEvent { round: 2, kind: FaultKind::Preempt { pick: 4 } },
        FaultEvent {
            round: 3,
            kind: FaultKind::Straggle { pick: 1, factor: 0.25, rounds: 3 },
        },
        FaultEvent { round: 4, kind: FaultKind::NodeFail(1) },
        FaultEvent { round: 8, kind: FaultKind::GpuRecover(2) },
        FaultEvent { round: 10, kind: FaultKind::NodeRecover(1) },
    ]);
    for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(2)] {
        let run = |budget: usize| {
            let _budget = WorkerPool::global().budget_override(budget);
            run_sim_faulted(kind, &trace, spec, scale.seed, &faults)
        };
        let a = run(1);
        let b = run(6);
        assert_eq!(a.unfinished, 0, "{kind:?}: faulted run must drain");
        assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits(), "{kind:?}");
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{kind:?}");
        assert_eq!(a.total_migrations, b.total_migrations, "{kind:?}");
        assert_eq!(a.rounds, b.rounds, "{kind:?}");
        assert_eq!(a.evictions, b.evictions, "{kind:?}");
        assert_eq!(a.preemptions, b.preemptions, "{kind:?}");
        assert_eq!(a.replacements, b.replacements, "{kind:?}");
        assert_eq!(a.stragglers, b.stragglers, "{kind:?}");
        assert_eq!(a.degraded_rounds, b.degraded_rounds, "{kind:?}");
        assert_eq!(a.outcomes.len(), b.outcomes.len(), "{kind:?}");
        for (id, oa) in &a.outcomes {
            assert_eq!(
                oa.jct.to_bits(),
                b.outcomes[id].jct.to_bits(),
                "{kind:?} job {id}: per-job progress diverged across budgets"
            );
            assert_eq!(oa.migrations, b.outcomes[id].migrations, "{kind:?} job {id}");
        }
        // The script must actually have bitten for the parity to mean
        // anything: GPU 2 and node 1 were busy when they died.
        assert!(a.evictions >= 1, "{kind:?}: no eviction fired");
    }
}

// ============================================================ sharding

/// ISSUE 9's wrapper contract: a one-shard coordinator routes every job
/// to a single sub-scheduler handed the whole cluster, the verbatim
/// previous plan and the verbatim health mask — so for every scheduler
/// family its decisions across churned rounds must be bit-identical to
/// running that scheduler directly (plans, strategies, packed pairs,
/// migration counts).
#[test]
fn one_shard_coordinator_is_bit_identical_to_unsharded() {
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::scalability::{churn_active_jobs, synthetic_active_jobs};
    use tesserae::experiments::{build_scheduler, SchedKind};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::{RoundInput, Scheduler};
    use tesserae::sharding::{ShardFactory, ShardedConfig, ShardedCoordinator};

    let spec = ClusterSpec::new(6, 4, GpuType::A100);
    for seed in [9u64, 31] {
        for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(2)] {
            let run = |wrapped: bool| {
                let truth = Profiler::new(spec.gpu_type, seed);
                let source: Arc<dyn ThroughputSource> =
                    Arc::new(CachedSource::new(OracleEstimator::new(truth)));
                let mut sched: Box<dyn Scheduler> = if wrapped {
                    let factory: ShardFactory = Arc::new(move |_shard| {
                        build_scheduler(kind, Arc::clone(&source), Arc::new(HungarianEngine))
                    });
                    Box::new(ShardedCoordinator::new(
                        ShardedConfig::new(1),
                        kind.label().as_str(),
                        factory,
                        Arc::new(HungarianEngine),
                    ))
                } else {
                    build_scheduler(kind, source, Arc::new(HungarianEngine))
                };
                let mut active = synthetic_active_jobs(40, seed);
                let mut prev = PlacementPlan::new(spec.total_gpus());
                let mut decisions = Vec::new();
                for round in 0..4u64 {
                    let d = sched.decide(&RoundInput {
                        now: round as f64 * 360.0,
                        round,
                        active: &active,
                        prev_plan: &prev,
                        spec: &spec,
                        health: None,
                    });
                    prev = d.plan.clone();
                    decisions.push((d.plan, d.strategies, d.packed_pairs, d.migrations));
                    active = churn_active_jobs(&active, seed ^ (round + 19));
                }
                decisions
            };
            let direct = run(false);
            let wrapped = run(true);
            assert_eq!(
                direct, wrapped,
                "{kind:?} seed {seed}: the one-shard wrapper changed the decisions"
            );
        }
    }
}

/// The sharded coordinator's faulted runs must be bit-identical across
/// worker-pool budgets: with budget 1 every shard decides inline in shard
/// order; with a real budget the shards decide concurrently on pool
/// workers. Per-job JCTs, migration totals, fault counters and round
/// counts must all agree — including through GPU/node failures that push
/// individual shards into eviction and recovery.
#[test]
fn sharded_faulted_simulation_is_bit_identical_across_pool_budgets() {
    use tesserae::experiments::faults::run_sim_faulted;
    use tesserae::experiments::{Scale, SchedKind};
    use tesserae::faults::{FaultEvent, FaultKind, FaultPlan};
    use tesserae::util::pool::WorkerPool;

    let scale = Scale {
        jobs: 14,
        nodes: 4,
        gpus_per_node: 4,
        jobs_per_hour: 240.0,
        seed: 5,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let faults = FaultPlan::from_events(vec![
        FaultEvent { round: 1, kind: FaultKind::GpuFail(2) },
        FaultEvent { round: 2, kind: FaultKind::Preempt { pick: 4 } },
        FaultEvent { round: 4, kind: FaultKind::NodeFail(1) },
        FaultEvent { round: 8, kind: FaultKind::GpuRecover(2) },
        FaultEvent { round: 10, kind: FaultKind::NodeRecover(1) },
    ]);
    let run = |budget: usize| {
        let _budget = WorkerPool::global().budget_override(budget);
        run_sim_faulted(SchedKind::Sharded(4), &trace, spec, scale.seed, &faults)
    };
    let a = run(1);
    let b = run(6);
    assert_eq!(a.unfinished, 0, "sharded faulted run must drain");
    assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits());
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.total_migrations, b.total_migrations);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.evictions, b.evictions);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.replacements, b.replacements);
    assert_eq!(a.stragglers, b.stragglers);
    assert_eq!(a.degraded_rounds, b.degraded_rounds);
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (id, oa) in &a.outcomes {
        assert_eq!(
            oa.jct.to_bits(),
            b.outcomes[id].jct.to_bits(),
            "job {id}: per-job progress diverged across budgets"
        );
        assert_eq!(oa.migrations, b.outcomes[id].migrations, "job {id}");
    }
    assert!(a.evictions >= 1, "no eviction fired");
}

// ============================================================= recovery

/// Bit-compare the recovery-relevant surface of two [`SimResult`]s —
/// everything the snapshot must preserve (wall-clock timings and
/// telemetry are deliberately out of scope).
fn assert_result_bits(
    a: &tesserae::simulator::SimResult,
    b: &tesserae::simulator::SimResult,
    label: &str,
) {
    assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits(), "{label}: avg_jct");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{label}: makespan");
    assert_eq!(a.total_migrations, b.total_migrations, "{label}: migrations");
    assert_eq!(a.rounds, b.rounds, "{label}: rounds");
    assert_eq!(a.evictions, b.evictions, "{label}: evictions");
    assert_eq!(a.preemptions, b.preemptions, "{label}: preemptions");
    assert_eq!(a.replacements, b.replacements, "{label}: replacements");
    assert_eq!(a.stragglers, b.stragglers, "{label}: stragglers");
    assert_eq!(a.degraded_rounds, b.degraded_rounds, "{label}: degraded");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (id, oa) in &a.outcomes {
        assert_eq!(
            oa.jct.to_bits(),
            b.outcomes[id].jct.to_bits(),
            "{label}: job {id} JCT diverged"
        );
        assert_eq!(oa.migrations, b.outcomes[id].migrations, "{label}: job {id}");
    }
}

/// ISSUE 10's restore contract: a run killed at round r and restored from
/// its latest snapshot must finish bit-identical to the uninterrupted run
/// — per-job JCTs, migration counts, fault counters — for every scheduler
/// family, including the sharded coordinator whose snapshot carries shard
/// routes and per-shard circuit breakers.
#[test]
fn killed_and_restored_runs_are_bit_identical_per_family() {
    use tesserae::experiments::{run_sim_recoverable, Scale, SchedKind};
    use tesserae::simulator::RecoveryOptions;

    let scale = Scale {
        jobs: 14,
        nodes: 4,
        gpus_per_node: 2,
        jobs_per_hour: 240.0,
        seed: 5,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    for kind in [
        SchedKind::TesseraeT,
        SchedKind::Gavel,
        SchedKind::Pop(2),
        SchedKind::Sharded(4),
    ] {
        let reference =
            run_sim_recoverable(kind, &trace, spec, scale.seed, 0.0, &RecoveryOptions::default());
        assert_eq!(reference.unfinished, 0, "{kind:?}: reference must drain");
        let dir = std::env::temp_dir().join(format!(
            "tesserae-prop-restore-{kind:?}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let killed = run_sim_recoverable(
            kind,
            &trace,
            spec,
            scale.seed,
            0.0,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 2,
                restore: false,
                stop_after_round: Some(4),
            },
        );
        assert!(
            killed.rounds < reference.rounds,
            "{kind:?}: kill at round 4 must interrupt ({} vs {})",
            killed.rounds,
            reference.rounds
        );
        let resumed = run_sim_recoverable(
            kind,
            &trace,
            spec,
            scale.seed,
            0.0,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 2,
                restore: true,
                stop_after_round: None,
            },
        );
        assert_result_bits(&reference, &resumed, &format!("{kind:?}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Restores must also be invariant to the execution environment: the same
/// kill-and-restore sequence run under a single-thread worker-pool budget,
/// a multi-thread budget, and with telemetry enabled must all land on the
/// uninterrupted result bit for bit. The sharded coordinator is the
/// sharpest probe — its shards decide on pool workers and its snapshot
/// round-trips per-shard breaker state.
#[test]
fn restored_runs_are_invariant_to_pool_budget_and_telemetry() {
    use tesserae::experiments::{run_sim_recoverable, Scale, SchedKind};
    use tesserae::simulator::RecoveryOptions;
    use tesserae::util::pool::WorkerPool;

    let scale = Scale {
        jobs: 12,
        nodes: 3,
        gpus_per_node: 2,
        jobs_per_hour: 240.0,
        seed: 7,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let kind = SchedKind::Sharded(3);
    let reference =
        run_sim_recoverable(kind, &trace, spec, scale.seed, 0.0, &RecoveryOptions::default());
    assert_eq!(reference.unfinished, 0, "reference must drain");

    for (budget, telemetry) in [(1usize, false), (6, false), (6, true)] {
        let _budget = WorkerPool::global().budget_override(budget);
        let _obs = tesserae::obs::enabled_guard(telemetry);
        let dir = std::env::temp_dir().join(format!(
            "tesserae-prop-env-{budget}-{telemetry}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let _killed = run_sim_recoverable(
            kind,
            &trace,
            spec,
            scale.seed,
            0.0,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: false,
                stop_after_round: Some(3),
            },
        );
        let resumed = run_sim_recoverable(
            kind,
            &trace,
            spec,
            scale.seed,
            0.0,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: true,
                stop_after_round: None,
            },
        );
        assert_result_bits(
            &reference,
            &resumed,
            &format!("budget={budget} telemetry={telemetry}"),
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

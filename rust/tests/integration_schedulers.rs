//! Integration tests over multi-round scheduler behaviour: plan validity,
//! migration stability, decision-time scaling and POP partitioning across
//! cluster topologies.

use std::sync::Arc;

use tesserae::cluster::{ClusterSpec, GpuType, PlacementPlan};
use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use tesserae::experiments::scalability::{measure_decision, synthetic_active_jobs};
use tesserae::experiments::{build_scheduler, SchedKind};
use tesserae::matching::{HungarianEngine, MatchingEngine};
use tesserae::profiler::Profiler;
use tesserae::schedulers::RoundInput;

fn source() -> Arc<dyn ThroughputSource> {
    Arc::new(CachedSource::new(OracleEstimator::new(Profiler::new(
        GpuType::A100,
        42,
    ))))
}

fn engine() -> Arc<dyn MatchingEngine> {
    Arc::new(HungarianEngine)
}

/// Drive `rounds` consecutive decisions with a fixed active set and check
/// plan invariants each round.
fn drive(kind: SchedKind, spec: ClusterSpec, n_jobs: usize, rounds: usize) -> Vec<usize> {
    let mut sched = build_scheduler(kind, source(), engine());
    let active = synthetic_active_jobs(n_jobs, 3);
    let mut prev = PlacementPlan::new(spec.total_gpus());
    let mut migrations = Vec::new();
    for round in 0..rounds {
        let d = sched.decide(&RoundInput {
            now: round as f64 * 360.0,
            round: round as u64,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        d.plan.validate().expect("invalid plan");
        // Every placed job occupies exactly its requested GPU count.
        for job in d.plan.jobs() {
            let got = d.plan.gpus_of(job).len() as u32;
            let want = active.iter().find(|j| j.id == job).unwrap().num_gpus;
            assert_eq!(got, want, "{}: job {job} got {got}/{want} gpus", sched.name());
        }
        migrations.push(d.migrations);
        prev = d.plan;
    }
    migrations
}

#[test]
fn tesserae_stabilizes_with_fixed_jobs() {
    let migr = drive(SchedKind::TesseraeT, ClusterSpec::new(4, 4, GpuType::A100), 30, 5);
    // After the first round the same active set must not churn.
    assert!(
        migr[1..].iter().all(|&m| m == 0),
        "migrations after stabilization: {migr:?}"
    );
}

#[test]
fn all_schedulers_produce_valid_plans_across_rounds() {
    for kind in [
        SchedKind::TesseraeT,
        SchedKind::Tiresias,
        SchedKind::TiresiasSingle,
        SchedKind::Gavel,
        SchedKind::GavelFtf,
        SchedKind::Pop(2),
    ] {
        drive(kind, ClusterSpec::new(4, 2, GpuType::A100), 20, 3);
    }
}

#[test]
fn pop_handles_odd_topologies() {
    // Partition counts that do not divide the node count.
    for k in [2usize, 3, 5] {
        drive(SchedKind::Pop(k), ClusterSpec::new(7, 2, GpuType::A100), 25, 2);
    }
}

#[test]
fn pop_shrinks_partitions_for_large_jobs() {
    // 8-GPU jobs on 2-GPU nodes need 4 nodes: POP-4 on 4 nodes must fall
    // back to fewer partitions rather than starving the job.
    let spec = ClusterSpec::new(4, 2, GpuType::A100);
    let mut sched = build_scheduler(SchedKind::Pop(4), source(), engine());
    let mut active = synthetic_active_jobs(6, 9);
    active[0].num_gpus = 8;
    active[0].attained_service = 0.0; // top priority
    let prev = PlacementPlan::new(spec.total_gpus());
    let d = sched.decide(&RoundInput {
        now: 0.0,
        round: 0,
        active: &active,
        prev_plan: &prev,
        spec: &spec,
        health: None,
    });
    assert_eq!(d.plan.gpus_of(active[0].id).len(), 8, "large job starved");
}

#[test]
fn decision_time_scales_mildly_for_tesserae() {
    let spec = ClusterSpec::scale_256();
    let small = measure_decision(SchedKind::TesseraeT, 250, &spec, 3).total_s;
    let large = measure_decision(SchedKind::TesseraeT, 2000, &spec, 3).total_s;
    // 8x the jobs must cost well under 64x the time (near-linear growth).
    assert!(
        large < small.max(1e-4) * 64.0,
        "tesserae decision super-cubic: {small} -> {large}"
    );
    // And stays within the paper's envelope.
    assert!(large < 1.6, "2000-job decision took {large}s");
}

#[test]
fn empty_active_set_yields_empty_plan() {
    let spec = ClusterSpec::new(2, 2, GpuType::A100);
    for kind in [SchedKind::TesseraeT, SchedKind::Gavel] {
        let mut sched = build_scheduler(kind, source(), engine());
        let prev = PlacementPlan::new(spec.total_gpus());
        let d = sched.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &[],
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(d.plan.jobs().is_empty());
        assert_eq!(d.migrations, 0);
    }
}

#[test]
fn exempt_jobs_never_packed_end_to_end() {
    use tesserae::policies::placement::PackingConfig;
    use tesserae::schedulers::{Scheduler, TesseraeScheduler};

    let spec = ClusterSpec::new(1, 2, GpuType::A100);
    let active = synthetic_active_jobs(6, 11);
    let exempt_id = active[0].id;
    let mut sched = TesseraeScheduler::tesserae_t(source(), engine());
    sched.packing = Some(PackingConfig {
        exempt: [exempt_id].into_iter().collect(),
        ..Default::default()
    });
    let prev = PlacementPlan::new(spec.total_gpus());
    let d = sched.decide(&RoundInput {
        now: 0.0,
        round: 0,
        active: &active,
        prev_plan: &prev,
        spec: &spec,
        health: None,
    });
    for (a, b) in &d.packed_pairs {
        assert_ne!(*a, exempt_id);
        assert_ne!(*b, exempt_id);
    }
}

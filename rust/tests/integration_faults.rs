//! End-to-end fault-injection scenario in its own test binary: the
//! `TESSERAE_FAULT_INJECT_STAGE` env knob is process-global, so this file
//! holds exactly one test — no concurrent test in this process can race
//! the env window. (The script-driven fault tests live in the simulator's
//! unit tests and `tests/properties.rs`; this binary covers the env-var
//! injection path plus the flight-recorder dump it must produce.)

use std::sync::Arc;

use tesserae::cluster::{ClusterSpec, GpuType};
use tesserae::estimator::OracleEstimator;
use tesserae::matching::HungarianEngine;
use tesserae::obs::recorder;
use tesserae::profiler::Profiler;
use tesserae::schedulers::{pipeline, TesseraeScheduler};
use tesserae::simulator::{simulate, SimConfig};
use tesserae::trace::{Trace, TraceParams};
use tesserae::util::json::Json;

/// A full simulation with a stage failure injected by env var mid-run:
/// the pipeline must fall back (not panic), the run must recover and
/// drain, and — with telemetry on — the failure must ship a flight-record
/// dump whose JSON has the documented shape, into a directory that does
/// not exist yet.
#[test]
fn injected_stage_failure_degrades_dumps_and_recovers() {
    let trace = Trace::shockwave(&TraceParams {
        num_jobs: 12,
        jobs_per_hour: 240.0,
        seed: 41,
    });
    let truth = Profiler::new(GpuType::A100, 42);
    let cfg = SimConfig::new(ClusterSpec::new(2, 4, GpuType::A100));
    let build = || {
        TesseraeScheduler::tesserae_t(
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42))),
            Arc::new(HungarianEngine),
        )
    };

    // Telemetry on so rounds are recorded and the degraded fallback has
    // something to dump; the guard also restores the previous state.
    let _g = tesserae::obs::enabled_guard(true);
    recorder::clear();

    let out_dir = std::env::temp_dir().join(format!(
        "tesserae_fault_it_{}/artifacts",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(out_dir.parent().unwrap());
    let dump_path = out_dir.join("flight.json");
    std::env::set_var(recorder::FLIGHT_OUT_ENV, &dump_path);
    std::env::set_var(pipeline::FAULT_INJECT_ENV, "pack@3");

    let r = simulate(&trace, &mut build(), &truth, &cfg);

    std::env::remove_var(pipeline::FAULT_INJECT_ENV);
    std::env::remove_var(recorder::FLIGHT_OUT_ENV);

    assert_eq!(r.degraded_rounds, 1, "pack@3 must degrade exactly round 3");
    assert_eq!(r.unfinished, 0, "the run must recover and drain");

    // The dump landed in a directory that didn't exist, and it has the
    // documented shape: context + rounds_held + rounds[{round, label,
    // total_s, metrics_delta, spans}].
    let text = std::fs::read_to_string(&dump_path)
        .expect("degraded fallback must write a flight dump");
    let doc = Json::parse(&text).expect("flight dump must be valid JSON");
    let context = doc.get("context").and_then(Json::as_str).unwrap();
    assert!(context.contains("degraded"), "context: {context}");
    assert!(doc.get("rounds_held").and_then(Json::as_f64).unwrap() >= 1.0);
    let rounds = doc.get("rounds").and_then(Json::as_arr).unwrap();
    assert!(!rounds.is_empty());
    for rec in rounds {
        for key in ["round", "label", "total_s", "metrics_delta", "spans"] {
            assert!(rec.get(key).is_some(), "round record missing '{key}'");
        }
    }

    // Determinism: the same injection replays bit-identically.
    std::env::set_var(pipeline::FAULT_INJECT_ENV, "pack@3");
    let r2 = simulate(&trace, &mut build(), &truth, &cfg);
    std::env::remove_var(pipeline::FAULT_INJECT_ENV);
    assert_eq!(r.avg_jct.to_bits(), r2.avg_jct.to_bits());
    assert_eq!(r.total_migrations, r2.total_migrations);
    assert_eq!(r2.degraded_rounds, 1);

    let _ = std::fs::remove_dir_all(out_dir.parent().unwrap());
    recorder::clear();
}

//! Integration tests over the full simulator stack: trace → scheduler →
//! placement policies → metrics, across all scheduler configurations.

use tesserae::cluster::GpuType;
use tesserae::experiments::{run_sim, Scale, SchedKind};
use tesserae::trace::{Trace, TraceParams};

fn scale() -> Scale {
    Scale::quick()
}

#[test]
fn headline_shape_tesserae_beats_tiresias() {
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let ours = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let base = run_sim(SchedKind::Tiresias, &trace, spec, s.seed, 0.0);
    assert_eq!(ours.unfinished, 0);
    assert_eq!(base.unfinished, 0);
    assert!(
        ours.avg_jct < base.avg_jct,
        "JCT: {} vs {}",
        ours.avg_jct,
        base.avg_jct
    );
    assert!(ours.makespan <= base.makespan * 1.05);
}

#[test]
fn packing_is_the_dominant_gain() {
    // Ablation consistency: no-pack Tesserae sits between full Tesserae and
    // plain Tiresias on JCT (migration helps, packing helps more).
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let full = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let nopack = run_sim(SchedKind::TesseraeTNoPack, &trace, spec, s.seed, 0.0);
    assert!(
        full.avg_jct <= nopack.avg_jct * 1.02,
        "packing should not hurt: {} vs {}",
        full.avg_jct,
        nopack.avg_jct
    );
}

#[test]
fn migration_algorithm_reduces_migrations_end_to_end() {
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let ours = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let basic = run_sim(SchedKind::TesseraeTBasicMigration, &trace, spec, s.seed, 0.0);
    assert!(
        ours.total_migrations < basic.total_migrations,
        "{} vs {}",
        ours.total_migrations,
        basic.total_migrations
    );
}

#[test]
fn ftf_scheduler_improves_worst_case_fairness() {
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let ours = run_sim(SchedKind::TesseraeFtf, &trace, spec, s.seed, 0.0);
    let gavel = run_sim(SchedKind::GavelFtf, &trace, spec, s.seed, 0.0);
    assert!(
        ours.worst_ftf() <= gavel.worst_ftf() * 1.1,
        "worst FTF {} vs {}",
        ours.worst_ftf(),
        gavel.worst_ftf()
    );
}

#[test]
fn gavel_trace_workload_also_wins() {
    let s = scale();
    let trace = s.gavel_trace();
    let spec = s.spec(GpuType::A100);
    let ours = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let base = run_sim(SchedKind::Tiresias, &trace, spec, s.seed, 0.0);
    assert_eq!(ours.unfinished, 0);
    assert!(ours.avg_jct <= base.avg_jct * 1.02);
}

#[test]
fn results_reproducible_across_runs() {
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let a = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let b = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    assert_eq!(a.avg_jct, b.avg_jct);
    assert_eq!(a.total_migrations, b.total_migrations);
    for (id, oa) in &a.outcomes {
        assert_eq!(oa.jct, b.outcomes[id].jct);
    }
}

#[test]
fn noise_degrades_gracefully() {
    // Fig. 16 shape: 100% profiling noise costs at most a modest JCT hit.
    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let clean = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 0.0);
    let noisy = run_sim(SchedKind::TesseraeT, &trace, spec, s.seed, 1.0);
    assert_eq!(noisy.unfinished, 0);
    assert!(
        noisy.avg_jct < clean.avg_jct * 1.5,
        "noise blew up JCT: {} vs {}",
        noisy.avg_jct,
        clean.avg_jct
    );
}

#[test]
fn saturated_cluster_still_drains() {
    // Heavy burst: 40 jobs arriving nearly at once on 4 GPUs.
    let trace = Trace::shockwave(&TraceParams {
        num_jobs: 40,
        jobs_per_hour: 4000.0,
        seed: 3,
    });
    let spec = tesserae::cluster::ClusterSpec::new(1, 4, GpuType::A100);
    let r = run_sim(SchedKind::TesseraeT, &trace, spec, 3, 0.0);
    assert_eq!(r.unfinished, 0, "saturated cluster failed to drain");
}

#[test]
fn single_job_runs_near_isolated_speed() {
    let trace = Trace::shockwave(&TraceParams {
        num_jobs: 1,
        jobs_per_hour: 80.0,
        seed: 5,
    });
    let spec = tesserae::cluster::ClusterSpec::new(2, 4, GpuType::A100);
    let r = run_sim(SchedKind::TesseraeT, &trace, spec, 5, 0.0);
    let outcome = r.outcomes.values().next().unwrap();
    // Alone on the cluster: FTF ratio ~ 1 (one round of quantization slack).
    assert!(outcome.ftf < 1.6, "ftf {}", outcome.ftf);
    assert_eq!(outcome.migrations, 0);
}

#[test]
fn v100_cluster_slower_but_complete() {
    let s = scale();
    let trace = s.shockwave_trace();
    let a = run_sim(SchedKind::TesseraeT, &trace, s.spec(GpuType::A100), s.seed, 0.0);
    let v = run_sim(SchedKind::TesseraeT, &trace, s.spec(GpuType::V100), s.seed, 0.0);
    assert_eq!(v.unfinished, 0);
    assert!(v.avg_jct > a.avg_jct, "V100 should be slower");
}

#[test]
fn serviced_scheduler_matches_sequential_reference_end_to_end() {
    // ISSUE 2's end-to-end parity acceptance: a full simulation with the
    // batched/pruned/cached matching service must reproduce the
    // per-instance sequential path bit-for-bit, per job.
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator};
    use tesserae::matching::{HungarianEngine, ServiceConfig};
    use tesserae::profiler::Profiler;
    use tesserae::schedulers::TesseraeScheduler;
    use tesserae::simulator::{simulate, SimConfig};

    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    let truth = Profiler::new(spec.gpu_type, s.seed);
    let build = || {
        TesseraeScheduler::tesserae_t(
            Arc::new(CachedSource::new(OracleEstimator::new(truth.clone()))),
            Arc::new(HungarianEngine),
        )
    };
    let cfg = SimConfig::new(spec);
    let mut serviced = build();
    let mut reference = build();
    reference.set_service_config(ServiceConfig::sequential_reference());
    let ra = simulate(&trace, &mut serviced, &truth, &cfg);
    let rb = simulate(&trace, &mut reference, &truth, &cfg);
    assert_eq!(ra.avg_jct.to_bits(), rb.avg_jct.to_bits());
    assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
    assert_eq!(ra.total_migrations, rb.total_migrations);
    assert_eq!(ra.rounds, rb.rounds);
    assert_eq!(ra.outcomes.len(), rb.outcomes.len());
    for (id, oa) in &ra.outcomes {
        let ob = &rb.outcomes[id];
        assert_eq!(oa.jct.to_bits(), ob.jct.to_bits(), "job {id}");
        assert_eq!(oa.migrations, ob.migrations, "job {id}");
    }
    // The serviced run must have exercised the new machinery: solves
    // happened, and fewer of them than instances generated.
    let instances: usize = ra.timings.iter().map(|t| t.matching.instances).sum();
    let solved: usize = ra.timings.iter().map(|t| t.matching.solved).sum();
    assert!(solved > 0);
    assert!(
        solved < instances,
        "service never avoided a solve: {solved} of {instances}"
    );
    let ref_solved: usize = rb.timings.iter().map(|t| t.matching.solved).sum();
    assert!(ref_solved >= instances, "reference must solve every instance");
}

#[test]
fn refactored_simulator_reproduces_seed_metrics_bit_for_bit() {
    // The refactor's parity contract: with gap skipping disabled the
    // simulator walks exactly the seed's round-by-round path, so the
    // skipping run must reproduce its metrics bit-for-bit on seeded
    // traces — across schedulers and both trace generators.
    use std::sync::Arc;
    use tesserae::estimator::{CachedSource, OracleEstimator, ThroughputSource};
    use tesserae::experiments::build_scheduler;
    use tesserae::matching::HungarianEngine;
    use tesserae::profiler::Profiler;
    use tesserae::simulator::{simulate, SimConfig};

    let params = TraceParams {
        num_jobs: 25,
        jobs_per_hour: 2.0, // sparse: real idle gaps between arrivals
        seed: 19,
    };
    let spec = tesserae::cluster::ClusterSpec::new(2, 4, GpuType::A100);
    for trace in [Trace::shockwave(&params), Trace::gavel(&params)] {
        for kind in [SchedKind::TesseraeT, SchedKind::Tiresias, SchedKind::Gavel] {
            let run = |skip: bool| {
                let truth = Profiler::new(spec.gpu_type, 19);
                let source: Arc<dyn ThroughputSource> =
                    Arc::new(CachedSource::new(OracleEstimator::new(truth.clone())));
                let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
                let mut cfg = SimConfig::new(spec);
                cfg.skip_idle_gaps = skip;
                simulate(&trace, sched.as_mut(), &truth, &cfg)
            };
            let a = run(true);
            let b = run(false);
            assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits(), "{kind:?}");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{kind:?}");
            assert_eq!(a.total_migrations, b.total_migrations, "{kind:?}");
            assert_eq!(a.rounds, b.rounds, "{kind:?}");
            assert_eq!(a.unfinished, 0, "{kind:?}");
            for (id, oa) in &a.outcomes {
                let ob = &b.outcomes[id];
                assert_eq!(oa.jct.to_bits(), ob.jct.to_bits(), "{kind:?} job {id}");
                assert_eq!(oa.ftf.to_bits(), ob.ftf.to_bits(), "{kind:?} job {id}");
                assert_eq!(oa.migrations, ob.migrations, "{kind:?} job {id}");
                assert_eq!(oa.rounds_run, ob.rounds_run, "{kind:?} job {id}");
            }
            // The sparse trace must actually exercise gap skipping.
            assert!(
                (a.timings.len() as u64) < a.rounds,
                "{kind:?}: no idle gaps ({} busy rounds of {})",
                a.timings.len(),
                a.rounds
            );
        }
    }
}

#[test]
fn pool_budget_one_vs_many_simulations_are_identical() {
    // ISSUE 4's pipeline parity acceptance: a full simulation with the
    // shared worker pool at budget 1 (every sharded stage inline) must be
    // bit-identical, per job, to the same simulation at a multi-thread
    // budget — across all three scheduler families.
    use tesserae::util::pool::WorkerPool;

    let s = scale();
    let trace = s.shockwave_trace();
    let spec = s.spec(GpuType::A100);
    for kind in [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(2)] {
        let run = |budget: usize| {
            let _budget = WorkerPool::global().budget_override(budget);
            run_sim(kind, &trace, spec, s.seed, 0.0)
        };
        let sequential = run(1);
        let sharded = run(8);
        assert_eq!(
            sequential.avg_jct.to_bits(),
            sharded.avg_jct.to_bits(),
            "{kind:?} avg JCT"
        );
        assert_eq!(
            sequential.makespan.to_bits(),
            sharded.makespan.to_bits(),
            "{kind:?} makespan"
        );
        assert_eq!(sequential.total_migrations, sharded.total_migrations, "{kind:?}");
        assert_eq!(sequential.rounds, sharded.rounds, "{kind:?}");
        assert_eq!(sequential.unfinished, 0, "{kind:?}");
        assert_eq!(sequential.outcomes.len(), sharded.outcomes.len(), "{kind:?}");
        for (id, oa) in &sequential.outcomes {
            let ob = &sharded.outcomes[id];
            assert_eq!(oa.jct.to_bits(), ob.jct.to_bits(), "{kind:?} job {id}");
            assert_eq!(oa.ftf.to_bits(), ob.ftf.to_bits(), "{kind:?} job {id}");
            assert_eq!(oa.migrations, ob.migrations, "{kind:?} job {id}");
            assert_eq!(oa.rounds_run, ob.rounds_run, "{kind:?} job {id}");
        }
    }
}

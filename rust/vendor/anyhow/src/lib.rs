//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! implements exactly the subset the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] and [`bail!`] macros, and the [`Context`] extension
//! trait. Semantics follow real anyhow where it matters here:
//!
//! * `{e}` (Display) prints the outermost message/context only;
//! * `{e:#}` (alternate Display) prints the whole context chain joined by
//!   `": "`, outermost first — what `main.rs` uses for error reports;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `Error` itself does **not** implement `std::error::Error` (exactly
//!   like real anyhow), which is what keeps the blanket `From` impl
//!   coherent.

use std::fmt;

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error carrying a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (mostly for tests).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result`s whose error type is a standard error.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("no such file"));
    }

    #[test]
    fn context_wraps_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert!(format!("{e:#}").starts_with("reading manifest: "));

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(format!("{e}"), "pass 2");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "fell through");
    }
}

//! Quickstart: schedule a small workload with Tesserae-T and a Tiresias
//! baseline, print the headline metrics.
//!
//!     cargo run --release --example quickstart

use tesserae::cluster::GpuType;
use tesserae::experiments::{run_sim, Scale, SchedKind};
use tesserae::util::benchutil::Table;

fn main() {
    // 120 jobs on 32 GPUs — the paper's physical-cluster shape (Fig. 9).
    let scale = Scale {
        jobs: 120,
        nodes: 8,
        gpus_per_node: 4,
        jobs_per_hour: 80.0,
        seed: 7,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);

    println!("simulating {} jobs on {} GPUs...", scale.jobs, spec.total_gpus());
    let ours = run_sim(SchedKind::TesseraeT, &trace, spec, scale.seed, 0.0);
    let base = run_sim(SchedKind::Tiresias, &trace, spec, scale.seed, 0.0);

    let mut t = Table::new(&["scheduler", "avg JCT (s)", "makespan (s)", "migrations"]);
    for r in [&ours, &base] {
        t.row(&[
            r.scheduler.clone(),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{}", r.total_migrations),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Tesserae-T speedup: {:.2}x JCT, {:.2}x makespan (paper: 1.62x / 1.15x)",
        base.avg_jct / ours.avg_jct,
        base.makespan / ours.makespan
    );
}

//! Full end-to-end simulation study: regenerates the paper's headline
//! comparisons (Figs. 9, 11, 12, 13, 17) at a configurable scale.
//!
//!     cargo run --release --example trace_sim -- --scale standard

use tesserae::experiments::{end_to_end, Scale};
use tesserae::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = match args.get_str("scale", "standard").as_str() {
        "quick" => Scale::quick(),
        "paper" => Scale::paper(),
        _ => Scale::standard(),
    };
    println!(
        "scale: {} jobs, {} GPUs\n",
        scale.jobs,
        scale.nodes * scale.gpus_per_node
    );
    let (fig9, _, _) = end_to_end::fig9_tesserae_vs_tiresias(&scale);
    println!("{fig9}");
    println!("{}", end_to_end::fig11_vs_gavel(&scale));
    println!("{}", end_to_end::fig12_vs_tiresias_single(&scale));
    println!("{}", end_to_end::fig13_ftf(&scale));
    println!("{}", end_to_end::fig17_gavel_trace(&scale));
}

//! Parallelism-strategy study: Fig. 8 (packing throughput vs strategy,
//! incl. the OOM case) and Fig. 15 (strategy impact on LLM JCT).
//!
//!     cargo run --release --example parallelism_packing

use tesserae::experiments::{ablations, Scale};
use tesserae::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = match args.get_str("scale", "standard").as_str() {
        "quick" => Scale::quick(),
        "paper" => Scale::paper(),
        _ => Scale::standard(),
    };
    println!("{}", ablations::fig8_parallelism_packing());
    println!("{}", ablations::fig15_strategy_impact(&scale));
}

//! End-to-end driver: the full three-layer system on a real workload.
//!
//! The rust coordinator (L3) schedules *actual* training jobs — the
//! AOT-exported GPT models (L2) whose attention runs through the Pallas
//! kernel (L1) — onto PJRT CPU worker devices, with Tesserae's packing and
//! migration policies making the placement decisions. Loss curves, measured
//! checkpoint traffic and JCTs are printed and logged for EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_train_cluster

use tesserae::coordinator::{run_cluster, ExecConfig, ExecJob};
use tesserae::policies::placement::MigrationMode;
use tesserae::util::benchutil::Table;

fn workload() -> Vec<ExecJob> {
    // A small arrival trace mixing both model sizes and multi-GPU jobs.
    vec![
        ExecJob { id: 1, model: "gpt-nano".into(), num_gpus: 1, arrival_round: 0, total_steps: 120 },
        ExecJob { id: 2, model: "gpt-micro".into(), num_gpus: 1, arrival_round: 0, total_steps: 60 },
        ExecJob { id: 3, model: "gpt-nano".into(), num_gpus: 2, arrival_round: 1, total_steps: 160 },
        ExecJob { id: 4, model: "gpt-nano".into(), num_gpus: 1, arrival_round: 1, total_steps: 80 },
        ExecJob { id: 5, model: "gpt-micro".into(), num_gpus: 1, arrival_round: 2, total_steps: 60 },
        ExecJob { id: 6, model: "gpt-nano".into(), num_gpus: 1, arrival_round: 3, total_steps: 100 },
    ]
}

fn main() -> anyhow::Result<()> {
    let cfg = ExecConfig {
        num_nodes: 2,
        gpus_per_node: 2,
        round_wall_s: 2.0,
        packing: true,
        migration: MigrationMode::Tesserae,
        seed: 1,
        max_rounds: 500,
    };
    println!(
        "real-execution cluster: {} nodes x {} GPUs, {}s rounds",
        cfg.num_nodes, cfg.gpus_per_node, cfg.round_wall_s
    );
    let report = run_cluster(&workload(), &cfg)?;

    let mut t = Table::new(&[
        "job", "model", "steps", "JCT (rounds)", "migrations", "first loss", "last loss",
    ]);
    for (id, j) in &report.jobs {
        t.row(&[
            format!("{id}"),
            j.model.clone(),
            format!("{}", j.steps),
            format!("{}", j.jct_rounds),
            format!("{}", j.migrations),
            format!("{:.3}", j.first_loss),
            format!("{:.3}", j.last_loss),
        ]);
    }
    println!("{}", t.render());
    println!(
        "rounds={} total migrations={} checkpoint traffic={:.1} MiB in {:.3}s wall={:.1}s",
        report.rounds,
        report.total_migrations,
        report.checkpoint_bytes as f64 / (1024.0 * 1024.0),
        report.checkpoint_time_s,
        report.wall_s,
    );

    // Log the loss curve of the longest job for EXPERIMENTS.md.
    let longest = report.jobs.values().max_by_key(|j| j.losses.len()).unwrap();
    println!("\nloss curve (job {} / {}):", longest.id, longest.model);
    let chunk_len = longest.losses.len().div_ceil(12).max(1);
    for (i, chunk) in longest.losses.chunks(chunk_len).enumerate() {
        let avg: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:>4}: {:.4}", i * chunk_len, avg);
    }
    let descended = report
        .jobs
        .values()
        .filter(|j| j.last_loss < j.first_loss)
        .count();
    println!(
        "\n{descended}/{} jobs ended with lower loss than they started",
        report.jobs.len()
    );
    anyhow::ensure!(descended == report.jobs.len(), "some jobs failed to learn");
    println!("e2e OK: all layers (rust coordinator -> PJRT -> JAX train step -> Pallas attention) composed.");
    Ok(())
}

//! Profiling-cost study: Fig. 18 (linear+BO vs matrix completion vs
//! oracle) and Fig. 16 (robustness to profiling noise).
//!
//!     cargo run --release --example profiling_estimators

use tesserae::experiments::{ablations, Scale};
use tesserae::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = match args.get_str("scale", "standard").as_str() {
        "quick" => Scale::quick(),
        "paper" => Scale::paper(),
        _ => Scale::standard(),
    };
    println!("{}", ablations::fig18_estimators(&scale));
    println!(
        "{}",
        ablations::fig16_noise_sensitivity(&scale, &[0.0, 0.2, 0.4, 0.6, 0.8, 1.0])
    );
}
